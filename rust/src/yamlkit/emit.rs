//! YAML and JSON emission for [`Value`] trees.

use super::Value;

/// Render a value as a YAML document (no leading `---`).
pub fn to_yaml_string(v: &Value) -> String {
    let mut out = String::new();
    emit_yaml(v, 0, false, &mut out);
    if !out.ends_with('\n') {
        out.push('\n');
    }
    out
}

fn needs_quotes(s: &str) -> bool {
    if s.is_empty() {
        return true;
    }
    // Strings that would re-parse as a different type, or contain YAML
    // syntax characters, must be quoted.
    let special = matches!(
        s,
        "true" | "false" | "null" | "~" | "True" | "False" | "Null"
    );
    let numeric = s.parse::<i64>().is_ok() || s.parse::<f64>().is_ok();
    special
        || numeric
        || s.contains(':')
        || s.contains('#')
        || s.contains('\n')
        || s.starts_with(['-', '[', '{', '&', '*', '!', '|', '>', '\'', '"', '%', '@'])
        || s.starts_with(' ')
        || s.ends_with(' ')
}

fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            _ => out.push(c),
        }
    }
    out.push('"');
    out
}

fn scalar_yaml(v: &Value) -> Option<String> {
    match v {
        Value::Null => Some("null".to_string()),
        Value::Bool(b) => Some(b.to_string()),
        Value::Int(i) => Some(i.to_string()),
        Value::Float(f) => Some(format_float(*f)),
        Value::Str(s) => Some(if needs_quotes(s) { quote(s) } else { s.clone() }),
        _ => None,
    }
}

fn format_float(f: f64) -> String {
    if f == f.trunc() && f.abs() < 1e15 {
        format!("{f:.1}")
    } else {
        format!("{f}")
    }
}

fn emit_yaml(v: &Value, indent: usize, _in_seq: bool, out: &mut String) {
    let pad = "  ".repeat(indent);
    match v {
        Value::Map(entries) if entries.is_empty() => out.push_str("{}\n"),
        Value::Seq(items) if items.is_empty() => out.push_str("[]\n"),
        Value::Map(entries) => {
            for (k, val) in entries {
                out.push_str(&pad);
                let key = if needs_quotes(k) { quote(k) } else { k.clone() };
                out.push_str(&key);
                out.push(':');
                match scalar_yaml(val) {
                    Some(s) => {
                        out.push(' ');
                        out.push_str(&s);
                        out.push('\n');
                    }
                    None => {
                        if matches!(val, Value::Map(m) if m.is_empty())
                            || matches!(val, Value::Seq(s) if s.is_empty())
                        {
                            out.push(' ');
                            emit_yaml(val, 0, false, out);
                        } else {
                            out.push('\n');
                            emit_yaml(val, indent + 1, false, out);
                        }
                    }
                }
            }
        }
        Value::Seq(items) => {
            for item in items {
                out.push_str(&pad);
                out.push_str("- ");
                match scalar_yaml(item) {
                    Some(s) => {
                        out.push_str(&s);
                        out.push('\n');
                    }
                    None => {
                        // Emit the nested structure with its first line
                        // inline after `- `.
                        let mut tmp = String::new();
                        emit_yaml(item, indent + 1, true, &mut tmp);
                        let trimmed = tmp.trim_start_matches(' ');
                        out.push_str(trimmed.lines().next().unwrap_or(""));
                        out.push('\n');
                        for line in trimmed.lines().skip(1) {
                            out.push_str(line);
                            out.push('\n');
                        }
                    }
                }
            }
        }
        scalar => {
            out.push_str(&pad);
            out.push_str(&scalar_yaml(scalar).unwrap());
            out.push('\n');
        }
    }
}

/// Render a value as compact JSON.
pub fn to_json_string(v: &Value) -> String {
    let mut out = String::new();
    emit_json(v, &mut out);
    out
}

fn emit_json(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => {
            if f.is_finite() {
                out.push_str(&format!("{f}"));
            } else {
                out.push_str("null");
            }
        }
        Value::Str(s) => out.push_str(&quote(s)),
        Value::Seq(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit_json(item, out);
            }
            out.push(']');
        }
        Value::Map(entries) => {
            out.push('{');
            for (i, (k, val)) in entries.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&quote(k));
                out.push(':');
                emit_json(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::parse_one;
    use super::*;

    #[test]
    fn yaml_roundtrip_pod() {
        let src = "apiVersion: v1\nkind: Pod\nmetadata:\n  name: demo\nspec:\n  containers:\n  - name: main\n    image: nginx\n";
        let v = parse_one(src).unwrap();
        let emitted = to_yaml_string(&v);
        let reparsed = parse_one(&emitted).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn yaml_roundtrip_tricky_scalars() {
        let mut v = Value::map();
        v.set("numeric_string", Value::from("8080"));
        v.set("with_colon", Value::from("a: b"));
        v.set("multiline", Value::from("l1\nl2"));
        v.set("boolish", Value::from("true"));
        v.set("int", Value::Int(-5));
        v.set("float", Value::Float(2.5));
        let reparsed = parse_one(&to_yaml_string(&v)).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn json_compact() {
        let v = parse_one("a: 1\nb:\n- x\n- y\n").unwrap();
        assert_eq!(to_json_string(&v), r#"{"a":1,"b":["x","y"]}"#);
    }

    #[test]
    fn roundtrip_seq_of_maps() {
        let src = "tasks:\n- name: a\n  deps:\n  - b\n  - c\n- name: b\n";
        let v = parse_one(src).unwrap();
        let reparsed = parse_one(&to_yaml_string(&v)).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn nested_seq_roundtrips() {
        use super::super::parse_one;
        // [[true, []]]
        let a = Value::Map(vec![("k0".to_string(), Value::Seq(vec![Value::Seq(vec![
            Value::Bool(true), Value::Seq(vec![])])]))]);
        // [[true], []]
        let b = Value::Map(vec![("k0".to_string(), Value::Seq(vec![
            Value::Seq(vec![Value::Bool(true)]), Value::Seq(vec![])]))]);
        for (i, t) in [a, b].iter().enumerate() {
            let e = to_yaml_string(t);
            let p = parse_one(&e).unwrap_or_else(|err| panic!("case {i}: {err}\n{e}"));
            assert_eq!(&p, t, "case {i}:\n{e}");
        }
    }

    #[test]
    fn empty_collections() {
        let mut v = Value::map();
        v.set("m", Value::map());
        v.set("s", Value::Seq(vec![]));
        let reparsed = parse_one(&to_yaml_string(&v)).unwrap();
        assert_eq!(v, reparsed);
    }
}
