//! Indentation-based YAML-subset parser (see module docs for the subset).

use super::Value;

/// Parse error with 1-based line information.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "yaml parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

fn err<T>(line: usize, message: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError { line, message: message.into() })
}

/// A logical line: indentation, content (comments stripped), line number.
struct Line {
    indent: usize,
    text: String,
    num: usize,
}

/// Strip a trailing comment that is not inside quotes.
fn strip_comment(s: &str) -> &str {
    let bytes = s.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b'\\' if in_double => i += 1,
            b'#' if !in_single && !in_double => {
                // YAML requires '#' to be preceded by whitespace (or BOL).
                if i == 0 || bytes[i - 1] == b' ' || bytes[i - 1] == b'\t' {
                    return &s[..i];
                }
            }
            _ => {}
        }
        i += 1;
    }
    s
}

/// Split a document chunk into logical lines. `offset` is the number of
/// source lines preceding the chunk, so `num` is file-absolute even for
/// documents after a `---` separator.
fn logical_lines(src: &str, offset: usize) -> Result<Vec<Line>, ParseError> {
    let mut out = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        let num = idx + 1 + offset;
        let no_comment = strip_comment(raw);
        let trimmed = no_comment.trim_end();
        if trimmed.trim().is_empty() {
            continue;
        }
        let content_start = trimmed.len() - trimmed.trim_start().len();
        // YAML forbids tabs in indentation: a tab has no defined column
        // width, so tolerating it silently misparses the structure.
        if trimmed[..content_start].contains('\t') {
            return err(
                num,
                "tab character in indentation (YAML forbids tabs; indent with spaces)",
            );
        }
        out.push(Line {
            indent: content_start,
            text: trimmed.trim_start().to_string(),
            num,
        });
    }
    Ok(out)
}

/// Parse a single-document source (the first document if several).
pub fn parse_one(src: &str) -> Result<Value, ParseError> {
    let docs = parse_all(src)?;
    Ok(docs.into_iter().next().unwrap_or(Value::Null))
}

/// Parse a multi-document source split on `---` lines. The `...`
/// end-of-document marker (as emitted by `kubectl get -o yaml`)
/// terminates the current document; only a `---` may follow it.
pub fn parse_all(src: &str) -> Result<Vec<Value>, ParseError> {
    let mut docs = Vec::new();
    let mut current = String::new();
    let mut line_offset = 0usize;
    // Set when a `...` marker closed the current document: any further
    // content before the next `---` is an error at the recorded line.
    let mut terminated = false;
    let mut starts = Vec::new();
    for (i, line) in src.lines().enumerate() {
        let t = line.trim();
        if t == "---" || t.starts_with("--- ") {
            starts.push((std::mem::take(&mut current), line_offset));
            terminated = false;
            line_offset = i + 1;
            if t.len() > 4 {
                // Inline document (`--- value`): content begins on the
                // marker line itself, so the chunk's offset is i, not i+1.
                line_offset = i;
                current.push_str(&line[line.find("--- ").unwrap() + 4..]);
                current.push('\n');
            }
        } else if t == "..." {
            starts.push((std::mem::take(&mut current), line_offset));
            terminated = true;
            line_offset = i + 1;
        } else if terminated {
            if !strip_comment(line).trim().is_empty() {
                return err(
                    i + 1,
                    "content after `...` end-of-document marker (expected `---`)",
                );
            }
        } else {
            current.push_str(line);
            current.push('\n');
        }
    }
    starts.push((current, line_offset));
    for (chunk, offset) in starts {
        if chunk.trim().is_empty() {
            continue;
        }
        let lines = logical_lines(&chunk, offset)?;
        if lines.is_empty() {
            continue;
        }
        let mut parser = Parser { lines, pos: 0 };
        let value = parser.parse_block(0)?;
        if parser.pos < parser.lines.len() {
            let l = &parser.lines[parser.pos];
            return err(l.num, format!("unexpected content: {:?}", l.text));
        }
        docs.push(value);
    }
    Ok(docs)
}

struct Parser {
    lines: Vec<Line>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Line> {
        self.lines.get(self.pos)
    }

    /// Parse a block node whose lines are indented at least `min_indent`.
    fn parse_block(&mut self, min_indent: usize) -> Result<Value, ParseError> {
        let first = match self.peek() {
            Some(l) if l.indent >= min_indent => l,
            _ => return Ok(Value::Null),
        };
        let indent = first.indent;
        if first.text.starts_with("- ") || first.text == "-" {
            self.parse_seq(indent)
        } else if looks_like_map_entry(&first.text) {
            self.parse_map(indent)
        } else {
            // A bare scalar or flow collection (single line).
            let line = &self.lines[self.pos];
            let v = parse_flow_or_scalar(&line.text, line.num)?;
            self.pos += 1;
            Ok(v)
        }
    }

    fn parse_seq(&mut self, indent: usize) -> Result<Value, ParseError> {
        let mut items = Vec::new();
        while let Some(l) = self.peek() {
            if l.indent != indent || !(l.text.starts_with("- ") || l.text == "-") {
                if l.indent > indent {
                    return err(l.num, "bad indentation in sequence");
                }
                break;
            }
            let num = l.num;
            let rest = if l.text == "-" { "" } else { &l.text[2..] }.to_string();
            self.pos += 1;
            if rest.is_empty() {
                // Nested block on following lines.
                items.push(self.parse_block(indent + 1)?);
            } else if rest.starts_with("- ") || rest == "-" {
                // Nested sequence starting inline: `- - item`.
                let entry_indent = indent + 2;
                self.lines.insert(
                    self.pos,
                    Line { indent: entry_indent, text: rest, num },
                );
                items.push(self.parse_seq(entry_indent)?);
            } else if looks_like_map_entry(&rest) {
                // Inline first entry of a mapping: `- name: x`.
                // Rewrite as a map whose first line is the rest, at a
                // virtual indent of indent+2.
                let entry_indent = indent + 2;
                self.lines.insert(
                    self.pos,
                    Line { indent: entry_indent, text: rest, num },
                );
                items.push(self.parse_map(entry_indent)?);
            } else {
                items.push(parse_flow_or_scalar(&rest, num)?);
            }
        }
        Ok(Value::Seq(items))
    }

    fn parse_map(&mut self, indent: usize) -> Result<Value, ParseError> {
        let mut entries: Vec<(String, Value)> = Vec::new();
        while let Some(l) = self.peek() {
            if l.indent != indent {
                if l.indent > indent {
                    return err(l.num, "bad indentation in mapping");
                }
                break;
            }
            let num = l.num;
            let text = l.text.clone();
            let (key, rest) = split_map_entry(&text, num)?;
            if entries.iter().any(|(k, _)| *k == key) {
                return err(num, format!("duplicate key {key:?}"));
            }
            self.pos += 1;
            let value = if rest.is_empty() {
                // Value is a nested block (or null).
                match self.peek() {
                    Some(next) if next.indent > indent => {
                        self.parse_block(indent + 1)?
                    }
                    // `key:` followed by a sequence at the same indent is
                    // also valid YAML.
                    Some(next)
                        if next.indent == indent
                            && (next.text.starts_with("- ")
                                || next.text == "-") =>
                    {
                        self.parse_seq(indent)?
                    }
                    _ => Value::Null,
                }
            } else if rest == "|" || rest == "|-" || rest == ">" || rest == ">-" {
                self.parse_block_scalar(indent, &rest)?
            } else {
                parse_flow_or_scalar(&rest, num)?
            };
            entries.push((key, value));
        }
        Ok(Value::Map(entries))
    }

    /// Literal (`|`) and folded (`>`) block scalars with optional strip.
    fn parse_block_scalar(
        &mut self,
        indent: usize,
        style: &str,
    ) -> Result<Value, ParseError> {
        let mut lines = Vec::new();
        while let Some(l) = self.peek() {
            if l.indent <= indent {
                break;
            }
            lines.push(l.text.clone());
            self.pos += 1;
        }
        let mut s = if style.starts_with('|') {
            lines.join("\n")
        } else {
            lines.join(" ")
        };
        if !style.ends_with('-') {
            s.push('\n');
        }
        Ok(Value::Str(s))
    }
}

/// True if the line starts a `key: ...` mapping entry.
fn looks_like_map_entry(text: &str) -> bool {
    split_map_entry(text, 0).is_ok()
}

/// Split `key: value` respecting quoted keys. Returns (key, rest).
fn split_map_entry(text: &str, num: usize) -> Result<(String, String), ParseError> {
    let bytes = text.as_bytes();
    let (key, after) = if bytes[0] == b'"' || bytes[0] == b'\'' {
        let quote = bytes[0];
        let mut i = 1;
        while i < bytes.len() && bytes[i] != quote {
            if quote == b'"' && bytes[i] == b'\\' {
                i += 1;
            }
            i += 1;
        }
        if i >= bytes.len() {
            return err(num, "unterminated quoted key");
        }
        // A quoted key must be followed by ':' — otherwise this line is
        // a plain quoted scalar, not a mapping entry.
        let after = text[i + 1..].trim_start();
        if !after.starts_with(':') {
            return err(num, "quoted scalar, not a mapping entry");
        }
        (unquote(&text[..=i], num)?, &text[i + 1..])
    } else {
        // Find a ':' that is followed by space/EOL and not inside flow.
        let mut depth = 0i32;
        let mut split = None;
        for (i, &b) in bytes.iter().enumerate() {
            match b {
                b'{' | b'[' => depth += 1,
                b'}' | b']' => depth -= 1,
                b':' if depth == 0 => {
                    if i + 1 == bytes.len() || bytes[i + 1] == b' ' {
                        split = Some(i);
                        break;
                    }
                }
                _ => {}
            }
        }
        match split {
            Some(i) => (text[..i].trim().to_string(), &text[i + 1..]),
            None => return err(num, format!("not a mapping entry: {text:?}")),
        }
    };
    let after = after.trim_start();
    let after = if let Some(stripped) = after.strip_prefix(':') {
        stripped.trim_start()
    } else {
        after
    };
    Ok((key, after.trim().to_string()))
}

fn unquote(s: &str, num: usize) -> Result<String, ParseError> {
    let bytes = s.as_bytes();
    if bytes.len() < 2 {
        return err(num, "bad quoted string");
    }
    let quote = bytes[0];
    let inner = &s[1..s.len() - 1];
    if quote == b'\'' {
        return Ok(inner.replace("''", "'"));
    }
    // Double-quoted: handle common escapes.
    let mut out = String::with_capacity(inner.len());
    let mut chars = inner.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('r') => out.push('\r'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some('0') => out.push('\0'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => return err(num, "dangling escape"),
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

/// Parse a value that may be flow syntax (`{..}` / `[..]`) or a scalar.
pub(super) fn parse_flow_or_scalar(s: &str, num: usize) -> Result<Value, ParseError> {
    let t = s.trim();
    if t.starts_with('{') || t.starts_with('[') {
        let mut p = FlowParser { src: t.as_bytes(), pos: 0, num };
        let v = p.parse_value()?;
        p.skip_ws();
        if p.pos != t.len() {
            return err(num, "trailing characters after flow value");
        }
        return Ok(v);
    }
    parse_scalar_checked(t, num)
}

fn parse_scalar_checked(t: &str, num: usize) -> Result<Value, ParseError> {
    if t.starts_with('&') || t.starts_with('*') {
        return err(num, "YAML anchors/aliases are not supported");
    }
    Ok(parse_scalar(t, num)?)
}

/// Plain scalar typing rules (null / bool / int / float / string).
fn parse_scalar(t: &str, num: usize) -> Result<Value, ParseError> {
    if t.is_empty() || t == "~" || t == "null" || t == "Null" || t == "NULL" {
        return Ok(Value::Null);
    }
    if t.starts_with('"') || t.starts_with('\'') {
        return Ok(Value::Str(unquote(t, num)?));
    }
    match t {
        "true" | "True" | "TRUE" => return Ok(Value::Bool(true)),
        "false" | "False" | "FALSE" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        // Leading zeros (e.g. "007") stay strings, like YAML 1.2 core.
        if !(t.len() > 1 && (t.starts_with('0') || t.starts_with("-0"))) {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = t.parse::<f64>() {
        if t.contains('.') || t.contains('e') || t.contains('E') {
            return Ok(Value::Float(f));
        }
    }
    Ok(Value::Str(t.to_string()))
}

/// Minimal flow-syntax parser for `{...}` and `[...]`.
struct FlowParser<'a> {
    src: &'a [u8],
    pos: usize,
    num: usize,
}

impl<'a> FlowParser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.src.len()
            && (self.src[self.pos] == b' ' || self.src[self.pos] == b'\t')
        {
            self.pos += 1;
        }
    }

    fn parse_value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.src.get(self.pos) {
            Some(b'{') => self.parse_flow_map(),
            Some(b'[') => self.parse_flow_seq(),
            Some(b'"') | Some(b'\'') => {
                let s = self.take_quoted()?;
                Ok(Value::Str(s))
            }
            Some(_) => {
                let num = self.num;
                let t = self.take_plain().trim().to_string();
                parse_scalar(&t, num)
            }
            None => err(self.num, "unexpected end of flow value"),
        }
    }

    fn parse_flow_map(&mut self) -> Result<Value, ParseError> {
        self.pos += 1; // '{'
        let mut entries = Vec::new();
        loop {
            self.skip_ws();
            match self.src.get(self.pos) {
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Map(entries));
                }
                None => return err(self.num, "unterminated flow map"),
                _ => {}
            }
            let key = match self.src.get(self.pos) {
                Some(b'"') | Some(b'\'') => self.take_quoted()?,
                _ => {
                    let start = self.pos;
                    while self.pos < self.src.len()
                        && self.src[self.pos] != b':'
                        && self.src[self.pos] != b'}'
                    {
                        self.pos += 1;
                    }
                    std::str::from_utf8(&self.src[start..self.pos])
                        .unwrap()
                        .trim()
                        .to_string()
                }
            };
            self.skip_ws();
            if self.src.get(self.pos) != Some(&b':') {
                return err(self.num, "expected ':' in flow map");
            }
            self.pos += 1;
            let value = self.parse_value()?;
            entries.push((key, value));
            self.skip_ws();
            match self.src.get(self.pos) {
                Some(b',') => self.pos += 1,
                Some(b'}') => {}
                _ => return err(self.num, "expected ',' or '}' in flow map"),
            }
        }
    }

    fn parse_flow_seq(&mut self) -> Result<Value, ParseError> {
        self.pos += 1; // '['
        let mut items = Vec::new();
        loop {
            self.skip_ws();
            match self.src.get(self.pos) {
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Seq(items));
                }
                None => return err(self.num, "unterminated flow sequence"),
                _ => {}
            }
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.src.get(self.pos) {
                Some(b',') => self.pos += 1,
                Some(b']') => {}
                _ => return err(self.num, "expected ',' or ']' in flow seq"),
            }
        }
    }

    fn take_quoted(&mut self) -> Result<String, ParseError> {
        let quote = self.src[self.pos];
        let start = self.pos;
        self.pos += 1;
        while self.pos < self.src.len() && self.src[self.pos] != quote {
            if quote == b'"' && self.src[self.pos] == b'\\' {
                self.pos += 1;
            }
            self.pos += 1;
        }
        if self.pos >= self.src.len() {
            return err(self.num, "unterminated quoted string");
        }
        self.pos += 1;
        unquote(
            std::str::from_utf8(&self.src[start..self.pos]).unwrap(),
            self.num,
        )
    }

    fn take_plain(&mut self) -> &str {
        let start = self.pos;
        while self.pos < self.src.len()
            && !matches!(self.src[self.pos], b',' | b']' | b'}')
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.src[start..self.pos]).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_pod_manifest() {
        let src = r#"
apiVersion: v1
kind: Pod
metadata:
  name: demo
  labels:
    app: web
spec:
  containers:
  - name: main
    image: nginx:1.25
    command: ["nginx", "-g", "daemon off;"]
    resources:
      requests:
        cpu: 2
        memory: 4Gi
"#;
        let v = parse_one(src).unwrap();
        assert_eq!(v.str_at("kind"), Some("Pod"));
        assert_eq!(v.str_at("metadata.labels.app"), Some("web"));
        assert_eq!(v.str_at("spec.containers.0.image"), Some("nginx:1.25"));
        let cmd = v.path("spec.containers.0.command").unwrap().as_seq().unwrap();
        assert_eq!(cmd.len(), 3);
        assert_eq!(v.i64_at("spec.containers.0.resources.requests.cpu"), Some(2));
        assert_eq!(
            v.str_at("spec.containers.0.resources.requests.memory"),
            Some("4Gi")
        );
    }

    #[test]
    fn parses_listing2_folded_scalar() {
        // The paper's Listing 2 uses `>-` for the annotation value.
        let src = "metadata:\n  annotations:\n    slurm-job.hpk.io/flags: >-\n      --ntasks=4\n      --exclusive\n";
        let v = parse_one(src).unwrap();
        // NB: annotation keys contain dots, so use get(), not path().
        let flags = v
            .path("metadata.annotations")
            .and_then(|a| a.get("slurm-job.hpk.io/flags"))
            .and_then(|f| f.as_str());
        assert_eq!(flags, Some("--ntasks=4 --exclusive"));
    }

    #[test]
    fn literal_block_scalar_keeps_newlines() {
        let src = "script: |\n  line one\n  line two\n";
        let v = parse_one(src).unwrap();
        assert_eq!(v.str_at("script"), Some("line one\nline two\n"));
    }

    #[test]
    fn multi_document() {
        let docs = parse_all("a: 1\n---\nb: 2\n---\nc: 3\n").unwrap();
        assert_eq!(docs.len(), 3);
        assert_eq!(docs[1].i64_at("b"), Some(2));
    }

    #[test]
    fn comments_stripped_quotes_respected() {
        let v = parse_one("a: \"x # not comment\" # comment\nb: 2\n").unwrap();
        assert_eq!(v.str_at("a"), Some("x # not comment"));
        assert_eq!(v.i64_at("b"), Some(2));
    }

    #[test]
    fn scalar_typing() {
        let v = parse_one(
            "i: 42\nneg: -3\nf: 1.5\nb: true\nn: null\ns: hello\nz: 007\nport: \"8080\"\n",
        )
        .unwrap();
        assert_eq!(v.path("i"), Some(&Value::Int(42)));
        assert_eq!(v.path("neg"), Some(&Value::Int(-3)));
        assert_eq!(v.path("f"), Some(&Value::Float(1.5)));
        assert_eq!(v.path("b"), Some(&Value::Bool(true)));
        assert_eq!(v.path("n"), Some(&Value::Null));
        assert_eq!(v.str_at("s"), Some("hello"));
        assert_eq!(v.str_at("z"), Some("007")); // leading zero stays string
        assert_eq!(v.str_at("port"), Some("8080"));
    }

    #[test]
    fn seq_of_scalars_and_nested_seq() {
        let v = parse_one("items:\n- 2\n- 4\n- 8\n- 16\n").unwrap();
        let items = v.path("items").unwrap().as_seq().unwrap();
        assert_eq!(items.len(), 4);
        assert_eq!(items[3], Value::Int(16));
    }

    #[test]
    fn withitems_inline_flow() {
        let v =
            parse_one("withItems: [{name: a, cpus: 2}, {name: b, cpus: 4}]\n")
                .unwrap();
        let items = v.path("withItems").unwrap().as_seq().unwrap();
        assert_eq!(items[1].i64_at("cpus"), Some(4));
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse_one("a: 1\na: 2\n").is_err());
    }

    #[test]
    fn anchors_rejected() {
        assert!(parse_one("a: &anchor 1\n").is_err());
    }

    #[test]
    fn key_with_slash_and_dots() {
        let v = parse_one("slurm-job.hpk.io/mpi-flags: \"-x LD_PRELOAD\"\n").unwrap();
        assert_eq!(
            v.get("slurm-job.hpk.io/mpi-flags").and_then(|f| f.as_str()),
            Some("-x LD_PRELOAD")
        );
    }

    #[test]
    fn empty_value_is_null_then_sibling() {
        let v = parse_one("a:\nb: 1\n").unwrap();
        assert_eq!(v.path("a"), Some(&Value::Null));
        assert_eq!(v.i64_at("b"), Some(1));
    }

    #[test]
    fn seq_at_same_indent_as_key() {
        let v = parse_one("tasks:\n- name: t1\n- name: t2\n").unwrap();
        assert_eq!(v.path("tasks").unwrap().as_seq().unwrap().len(), 2);
    }

    #[test]
    fn multi_document_errors_report_file_absolute_lines() {
        // The duplicate key sits in the third document, on file line 6.
        let e = parse_all("a: 1\n---\nb: 2\n---\nc: 3\nc: 4\n").unwrap_err();
        assert_eq!(e.line, 6, "got: {e}");
        assert!(e.message.contains("duplicate key"), "got: {e}");
    }

    #[test]
    fn inline_document_errors_report_marker_line() {
        // `--- &x 1` puts the document on the marker line itself (line 2).
        let e = parse_all("a: 1\n--- &x 1\n").unwrap_err();
        assert_eq!(e.line, 2, "got: {e}");
    }

    #[test]
    fn tab_indentation_rejected_with_line() {
        let e = parse_one("a:\n\tb: 1\n").unwrap_err();
        assert_eq!(e.line, 2, "got: {e}");
        assert!(e.message.contains("tab"), "got: {e}");
    }

    #[test]
    fn end_of_document_marker_terminates() {
        let docs = parse_all("a: 1\n...\n").unwrap();
        assert_eq!(docs.len(), 1);
        assert_eq!(docs[0].i64_at("a"), Some(1));
        let docs = parse_all("a: 1\n...\n---\nb: 2\n...\n").unwrap();
        assert_eq!(docs.len(), 2);
        assert_eq!(docs[1].i64_at("b"), Some(2));
    }

    #[test]
    fn content_after_end_marker_rejected() {
        let e = parse_all("a: 1\n...\nb: 2\n").unwrap_err();
        assert_eq!(e.line, 3, "got: {e}");
        assert!(e.message.contains("..."), "got: {e}");
    }
}
