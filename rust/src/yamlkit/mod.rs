//! YAML-subset + JSON parsing and emission.
//!
//! Kubernetes manifests are YAML and HPK's artifact manifest is JSON; no
//! serde/serde_yaml is available in this offline environment, so this
//! module implements the subset both need from scratch:
//!
//! - block mappings and sequences (indentation-based)
//! - inline (flow) maps `{a: 1}` and lists `[1, 2]`
//! - plain / single- / double-quoted scalars, comments, `---` documents
//! - the `...` end-of-document marker (`kubectl get -o yaml` emits it)
//! - block scalars `|`, `|-`, `>`, `>-` (Listing 2 of the paper uses `>-`)
//! - anchors are NOT supported (rejected with an error), matching the
//!   subset Kubernetes examples in the paper actually use.
//!
//! [`ParseError`] line numbers are **file-absolute** — an error in the
//! third document of a multi-document file points at the real line,
//! not at an offset within the chunk — and tab indentation is rejected
//! with the offending line named. The typed layer above this one is
//! [`crate::kube::manifest`]; the end-to-end consumer is the scenario
//! harness (`docs/SCENARIOS.md`).
//!
//! The [`Value`] tree preserves mapping order (kubectl-style round-trips).

mod parse;
mod emit;
mod json;
mod path;

pub use emit::{to_json_string, to_yaml_string};
pub use json::parse_json;
pub use parse::{parse_all, parse_one, ParseError};

/// An ordered YAML/JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Seq(Vec<Value>),
    /// Order-preserving mapping (manifests are small; linear lookup).
    Map(Vec<(String, Value)>),
}

impl Value {
    /// Empty mapping.
    pub fn map() -> Value {
        Value::Map(Vec::new())
    }

    /// Look up a key in a mapping.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Map(entries) => {
                entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Mutable lookup.
    pub fn get_mut(&mut self, key: &str) -> Option<&mut Value> {
        match self {
            Value::Map(entries) => {
                entries.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Walk a `.`-separated path, e.g. `spec.template.metadata.name`.
    pub fn path(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = match part.parse::<usize>() {
                Ok(idx) => match cur {
                    Value::Seq(items) => items.get(idx)?,
                    _ => cur.get(part)?,
                },
                Err(_) => cur.get(part)?,
            };
        }
        Some(cur)
    }

    /// Insert or replace a key in a mapping (no-op on non-maps).
    pub fn set(&mut self, key: &str, value: Value) {
        if let Value::Map(entries) = self {
            for (k, v) in entries.iter_mut() {
                if k == key {
                    *v = value;
                    return;
                }
            }
            entries.push((key.to_string(), value));
        }
    }

    /// Remove a key from a mapping, returning its value.
    pub fn remove(&mut self, key: &str) -> Option<Value> {
        if let Value::Map(entries) = self {
            if let Some(pos) = entries.iter().position(|(k, _)| k == key) {
                return Some(entries.remove(pos).1);
            }
        }
        None
    }

    /// Ensure `key` maps to a mapping, creating it if missing, and return
    /// a mutable reference to it.
    pub fn entry_map(&mut self, key: &str) -> &mut Value {
        if let Value::Map(entries) = self {
            if !entries.iter().any(|(k, _)| k == key) {
                entries.push((key.to_string(), Value::map()));
            }
            return entries
                .iter_mut()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .unwrap();
        }
        panic!("entry_map on non-map value");
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// String view with scalar coercion (ints/bools/floats render).
    pub fn coerce_string(&self) -> Option<String> {
        match self {
            Value::Str(s) => Some(s.clone()),
            Value::Int(i) => Some(i.to_string()),
            Value::Float(f) => Some(format!("{f}")),
            Value::Bool(b) => Some(b.to_string()),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Str(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            Value::Str(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_seq(&self) -> Option<&[Value]> {
        match self {
            Value::Seq(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Map(entries) => Some(entries),
            _ => None,
        }
    }

    /// Convenience: string at a path.
    pub fn str_at(&self, path: &str) -> Option<&str> {
        self.path(path).and_then(|v| v.as_str())
    }

    /// Convenience: i64 at a path.
    pub fn i64_at(&self, path: &str) -> Option<i64> {
        self.path(path).and_then(|v| v.as_i64())
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}

impl From<i64> for Value {
    fn from(i: i64) -> Value {
        Value::Int(i)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}

/// Build a `Value::Map` from key/value pairs.
#[macro_export]
macro_rules! vmap {
    ($($k:expr => $v:expr),* $(,)?) => {
        $crate::yamlkit::Value::Map(vec![
            $(($k.to_string(), $crate::yamlkit::Value::from($v))),*
        ])
    };
}

pub use path::merge_patch;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_walks_nested_maps_and_seqs() {
        let v = parse_one(
            "spec:\n  containers:\n  - name: main\n    image: busybox\n",
        )
        .unwrap();
        assert_eq!(v.str_at("spec.containers.0.name"), Some("main"));
        assert_eq!(v.str_at("spec.containers.0.image"), Some("busybox"));
    }

    #[test]
    fn set_replaces_and_appends() {
        let mut v = Value::map();
        v.set("a", Value::Int(1));
        v.set("a", Value::Int(2));
        v.set("b", Value::Int(3));
        assert_eq!(v.i64_at("a"), Some(2));
        assert_eq!(v.i64_at("b"), Some(3));
    }

    #[test]
    fn entry_map_creates_nested() {
        let mut v = Value::map();
        v.entry_map("metadata").set("name", Value::from("x"));
        assert_eq!(v.str_at("metadata.name"), Some("x"));
    }

    #[test]
    fn coerce_string_renders_scalars() {
        assert_eq!(Value::Int(5).coerce_string().unwrap(), "5");
        assert_eq!(Value::Bool(true).coerce_string().unwrap(), "true");
        assert!(Value::Seq(vec![]).coerce_string().is_none());
    }
}
