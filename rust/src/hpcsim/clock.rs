//! Hybrid virtual clock — the single source of time for the control
//! plane.
//!
//! # Time model
//!
//! Every duration in the system is one of two currencies:
//!
//! - **sim-ms** — milliseconds of *cluster life*: job time limits,
//!   backfill windows, GC tombstone TTLs, cron minutes, HPA
//!   stabilization, load-curve pacing, resync backstops. All of these
//!   flow through [`Clock::now_ms`] / [`Clock::sleep_sim`] (or the
//!   deadline-safe waits built on them, see below) and never touch the
//!   wall clock directly.
//! - **real-ms** — milliseconds of *host* time: perf measurement
//!   ([`Clock::real_ms`]) and the test harness' own patience
//!   ([`crate::util::sub::wait_for`] deadlines). Real compute (PJRT
//!   executions, query processing) also takes the real time it takes.
//!
//! A `Clock` runs in one of two modes:
//!
//! - **Scaled** ([`Clock::new`]) — `now_ms` advances with real time
//!   multiplied by `scale`, so queueing dynamics behave like the
//!   paper's wall-clock while tests stay fast. `sleep_sim` sleeps the
//!   corresponding real time, with a fractional-microsecond carry
//!   accumulator so sub-scale sleeps average out exactly instead of
//!   being stretched to a 1 µs floor each.
//! - **Driven** ([`Clock::driven`], [`Clock::driven_auto`]) — time is
//!   frozen until someone calls [`Clock::advance_ms`]. Waiters register
//!   virtual deadlines with [`Clock::notify_at`] and are fired in
//!   strict `(deadline, registration)` order as the advance sweeps past
//!   them, so the same seeded scenario replays **byte-identically** at
//!   maximum speed with zero wall-clock sleeps: an hour of cluster life
//!   costs exactly the compute it contains. `driven_auto` additionally
//!   makes `sleep_sim` advance the clock itself — the single-driver
//!   replay mode where the driving thread's own pacing is the only
//!   source of progress.
//!
//! # Deadline-safe APIs
//!
//! Code that must wait "until sim time T or an event" must not compute
//! a real timeout from sim-ms itself (that deadlocks a driven clock).
//! Use the clock-aware primitives instead, which park on
//! [`Clock::notify_at`] in driven mode and on a scaled real timeout
//! otherwise:
//!
//! - [`crate::util::Subscription::wait_sim`] — one park with a virtual
//!   deadline;
//! - [`crate::util::sub::wait_for_sim`] — the condition-poll loop over
//!   it;
//! - [`crate::slurm::CancelToken::wait_sim`] — cancellable virtual
//!   sleeps inside executors and container entrypoints.
//!
//! See `docs/TIME.md` for a worked driven-mode replay example.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Callback fired when a driven clock sweeps past a registered
/// deadline. Must not block: it runs on the advancing thread.
pub type TimerWaker = Arc<dyn Fn() + Send + Sync>;

/// Handle for cancelling a registered [`Clock::notify_at`] timer.
/// Dropping the id does *not* cancel (call [`Clock::cancel_notify`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimerId {
    key: (u64, u64),
}

struct DrivenState {
    now_ms: u64,
    closed: bool,
    next_id: u64,
    /// Registered waiters, keyed `(deadline sim-ms, registration id)`
    /// — BTreeMap order *is* the wake order.
    timers: BTreeMap<(u64, u64), TimerWaker>,
}

enum ModeState {
    Scaled {
        /// Fractional sim-µs not yet slept (always `< scale`).
        carry_us: Mutex<u64>,
    },
    Driven {
        state: Mutex<DrivenState>,
        cond: Condvar,
        /// `sleep_sim` advances the clock itself (single-driver replay).
        auto: bool,
        /// Timers fired by an advance reaching their deadline (close-
        /// time drains are not counted) — the zero-idle-wakeups hook.
        fired: AtomicU64,
    },
}

struct Inner {
    scale: u64,
    start: Instant,
    mode: ModeState,
}

/// The cluster clock. Cheap to clone (shared state). See the module
/// docs for the time model.
#[derive(Clone)]
pub struct Clock {
    inner: Arc<Inner>,
}

impl Clock {
    /// A scaled clock: `now_ms` = real elapsed ms × `scale`.
    pub fn new(scale: u64) -> Clock {
        Clock {
            inner: Arc::new(Inner {
                scale: scale.max(1),
                start: Instant::now(),
                mode: ModeState::Scaled { carry_us: Mutex::new(0) },
            }),
        }
    }

    /// A driven clock starting at sim-ms 0: time moves only via
    /// [`Clock::advance_ms`].
    pub fn driven() -> Clock {
        Clock::driven_with(false)
    }

    /// A driven clock whose `sleep_sim` advances the clock itself —
    /// for single-driver replays where the driving loop's pacing is
    /// the only source of progress.
    pub fn driven_auto() -> Clock {
        Clock::driven_with(true)
    }

    fn driven_with(auto: bool) -> Clock {
        Clock {
            inner: Arc::new(Inner {
                scale: 1,
                start: Instant::now(),
                mode: ModeState::Driven {
                    state: Mutex::new(DrivenState {
                        now_ms: 0,
                        closed: false,
                        next_id: 0,
                        timers: BTreeMap::new(),
                    }),
                    cond: Condvar::new(),
                    auto,
                    fired: AtomicU64::new(0),
                },
            }),
        }
    }

    pub fn is_driven(&self) -> bool {
        matches!(self.inner.mode, ModeState::Driven { .. })
    }

    /// Simulated milliseconds since cluster boot.
    pub fn now_ms(&self) -> u64 {
        match &self.inner.mode {
            ModeState::Scaled { .. } => {
                self.inner.start.elapsed().as_millis() as u64 * self.inner.scale
            }
            ModeState::Driven { state, .. } => state.lock().unwrap().now_ms,
        }
    }

    /// Real milliseconds since cluster boot (for perf measurement).
    pub fn real_ms(&self) -> u64 {
        self.inner.start.elapsed().as_millis() as u64
    }

    /// Sim-to-real conversion for timeout computation: `Some(real
    /// duration)` in scaled mode, `None` in driven mode (where no real
    /// duration corresponds — park on [`Clock::notify_at`] instead).
    pub fn sim_to_real(&self, sim_ms: u64) -> Option<Duration> {
        match &self.inner.mode {
            ModeState::Scaled { .. } => Some(Duration::from_micros(
                sim_ms.saturating_mul(1000) / self.inner.scale,
            )),
            ModeState::Driven { .. } => None,
        }
    }

    /// Sleep for `sim_ms` simulated milliseconds.
    ///
    /// Scaled: sleeps the scaled-down real time, carrying fractional
    /// microseconds so repeated sub-scale sleeps average out exactly.
    /// Driven: parks until the clock is advanced past the deadline
    /// (or closed); with [`Clock::driven_auto`], advances the clock
    /// itself instead of parking.
    pub fn sleep_sim(&self, sim_ms: u64) {
        match &self.inner.mode {
            ModeState::Scaled { carry_us } => {
                let real_us = {
                    let mut carry = carry_us.lock().unwrap();
                    let total_us = sim_ms.saturating_mul(1000) + *carry;
                    *carry = total_us % self.inner.scale;
                    total_us / self.inner.scale
                };
                if real_us > 0 {
                    std::thread::sleep(Duration::from_micros(real_us));
                }
            }
            ModeState::Driven { state, cond, auto, .. } => {
                if *auto {
                    self.advance_ms(sim_ms);
                    return;
                }
                let mut st = state.lock().unwrap();
                let deadline = st.now_ms.saturating_add(sim_ms);
                while st.now_ms < deadline && !st.closed {
                    st = cond.wait(st).unwrap();
                }
            }
        }
    }

    /// The scheduler tick: a short real-time pause (scaled) or a park
    /// until time moves (driven; one sim-ms advance in auto mode).
    pub fn tick(&self) {
        match &self.inner.mode {
            ModeState::Scaled { .. } => std::thread::sleep(Duration::from_millis(1)),
            ModeState::Driven { state, cond, auto, .. } => {
                if *auto {
                    self.advance_ms(1);
                    return;
                }
                let mut st = state.lock().unwrap();
                let t0 = st.now_ms;
                while st.now_ms == t0 && !st.closed {
                    st = cond.wait(st).unwrap();
                }
            }
        }
    }

    /// Advance a driven clock by `delta_ms`, firing every registered
    /// timer whose deadline the sweep passes, in strict `(deadline,
    /// registration)` order. Timers fire with the clock lock released
    /// (a waker may re-enter the clock); with a single advancing thread
    /// the order is still fully deterministic. No-op on a scaled clock.
    pub fn advance_ms(&self, delta_ms: u64) {
        let ModeState::Driven { state, cond, fired, .. } = &self.inner.mode else {
            return;
        };
        let target = {
            let st = state.lock().unwrap();
            st.now_ms.saturating_add(delta_ms)
        };
        loop {
            let waker = {
                let mut st = state.lock().unwrap();
                if st.closed {
                    return;
                }
                match st.timers.first_key_value() {
                    Some((&key, _)) if key.0 <= target => {
                        let waker = st.timers.remove(&key).unwrap();
                        st.now_ms = st.now_ms.max(key.0);
                        fired.fetch_add(1, Ordering::Relaxed);
                        cond.notify_all();
                        Some(waker)
                    }
                    _ => {
                        st.now_ms = target;
                        cond.notify_all();
                        None
                    }
                }
            };
            match waker {
                Some(w) => w(),
                None => return,
            }
        }
    }

    /// Register `waker` to fire when a driven clock reaches
    /// `deadline_ms`. Returns `None` if no timer was registered —
    /// either in scaled mode (nothing fires timers there and the waker
    /// is *not* called: compute a real timeout via
    /// [`Clock::sim_to_real`] instead), or because the deadline is
    /// already due / the clock is closed, in which case the waker
    /// fires immediately on this thread.
    pub fn notify_at(&self, deadline_ms: u64, waker: TimerWaker) -> Option<TimerId> {
        let ModeState::Driven { state, .. } = &self.inner.mode else {
            return None;
        };
        {
            let mut st = state.lock().unwrap();
            if !st.closed && deadline_ms > st.now_ms {
                let id = st.next_id;
                st.next_id += 1;
                let key = (deadline_ms, id);
                st.timers.insert(key, waker);
                return Some(TimerId { key });
            }
        }
        waker();
        None
    }

    /// Cancel a timer registered with [`Clock::notify_at`] (no-op if
    /// it already fired).
    pub fn cancel_notify(&self, id: TimerId) {
        if let ModeState::Driven { state, .. } = &self.inner.mode {
            state.lock().unwrap().timers.remove(&id.key);
        }
    }

    /// Close a driven clock: fires and drains all registered timers,
    /// wakes every parked sleeper, and makes further virtual waits
    /// return immediately — the shutdown edge that keeps a frozen
    /// clock from wedging its waiters. No-op on a scaled clock.
    pub fn close(&self) {
        let ModeState::Driven { state, cond, .. } = &self.inner.mode else {
            return;
        };
        let drained: Vec<TimerWaker> = {
            let mut st = state.lock().unwrap();
            st.closed = true;
            cond.notify_all();
            std::mem::take(&mut st.timers).into_values().collect()
        };
        for w in drained {
            w();
        }
    }

    /// Whether a driven clock has been closed (always `false` for
    /// scaled clocks).
    pub fn is_closed(&self) -> bool {
        match &self.inner.mode {
            ModeState::Scaled { .. } => false,
            ModeState::Driven { state, .. } => state.lock().unwrap().closed,
        }
    }

    /// Timers fired by advances reaching their deadlines — the
    /// regression hook proving an idle driven cluster performs zero
    /// wakeups. Always 0 for scaled clocks.
    pub fn timer_wakeups(&self) -> u64 {
        match &self.inner.mode {
            ModeState::Scaled { .. } => 0,
            ModeState::Driven { fired, .. } => fired.load(Ordering::Relaxed),
        }
    }

    pub fn scale(&self) -> u64 {
        self.inner.scale
    }

    #[cfg(test)]
    fn carry_us(&self) -> u64 {
        match &self.inner.mode {
            ModeState::Scaled { carry_us } => *carry_us.lock().unwrap(),
            ModeState::Driven { .. } => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_time_advances_faster() {
        let c = Clock::new(50);
        let t0 = c.now_ms();
        std::thread::sleep(Duration::from_millis(20));
        let dt = c.now_ms() - t0;
        assert!(dt >= 500, "expected >=500 sim ms, got {dt}");
    }

    #[test]
    fn sleep_sim_compresses() {
        let c = Clock::new(100);
        let t0 = Instant::now();
        c.sleep_sim(1000); // 1 simulated second ~ 10 real ms
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn sleep_sim_carries_fractions() {
        // scale 7: sleep_sim(1) = 1000/7 = 142 µs + 6 carried.
        let c = Clock::new(7);
        c.sleep_sim(1);
        assert_eq!(c.carry_us(), 1000 % 7);
        // Sub-scale sleeps accumulate instead of flooring to 1 µs.
        let c = Clock::new(1_000_000);
        for k in 1..=5u64 {
            c.sleep_sim(1);
            assert_eq!(c.carry_us(), (k * 1000) % 1_000_000);
        }
    }

    #[test]
    fn driven_clock_is_frozen_until_advanced() {
        let c = Clock::driven();
        assert!(c.is_driven());
        assert_eq!(c.now_ms(), 0);
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(c.now_ms(), 0, "driven time never moves on its own");
        c.advance_ms(3_600_000);
        assert_eq!(c.now_ms(), 3_600_000);
        assert_eq!(c.timer_wakeups(), 0, "idle advance fires nothing");
    }

    #[test]
    fn timers_fire_in_deadline_then_registration_order() {
        let c = Clock::driven();
        let log = Arc::new(Mutex::new(Vec::new()));
        let push = |tag: &'static str| {
            let log = log.clone();
            Arc::new(move || log.lock().unwrap().push(tag)) as TimerWaker
        };
        // Registered out of deadline order; b and c share a deadline,
        // so registration order breaks the tie.
        assert!(c.notify_at(200, push("b")).is_some());
        assert!(c.notify_at(200, push("c")).is_some());
        assert!(c.notify_at(100, push("a")).is_some());
        assert!(c.notify_at(900, push("z")).is_some());
        c.advance_ms(500);
        assert_eq!(*log.lock().unwrap(), vec!["a", "b", "c"]);
        assert_eq!(c.timer_wakeups(), 3);
        c.advance_ms(500);
        assert_eq!(*log.lock().unwrap(), vec!["a", "b", "c", "z"]);
    }

    #[test]
    fn due_timer_fires_immediately_and_cancel_prevents_fire() {
        let c = Clock::driven();
        c.advance_ms(50);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        let waker: TimerWaker = Arc::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        });
        // Already due: fires on this thread, no registration.
        assert!(c.notify_at(50, waker.clone()).is_none());
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        // Cancelled before due: never fires.
        let id = c.notify_at(100, waker).unwrap();
        c.cancel_notify(id);
        c.advance_ms(100);
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.timer_wakeups(), 0);
    }

    #[test]
    fn close_drains_timers_and_unparks_sleepers() {
        let c = Clock::driven();
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        c.notify_at(
            1_000,
            Arc::new(move || {
                h.fetch_add(1, Ordering::Relaxed);
            }),
        );
        let sleeper = c.clone();
        let handle = std::thread::spawn(move || sleeper.sleep_sim(10_000));
        c.close();
        handle.join().unwrap();
        assert!(c.is_closed());
        assert_eq!(hits.load(Ordering::Relaxed), 1, "close fires pending timers");
        assert_eq!(c.timer_wakeups(), 0, "close-drain is not a deadline fire");
        // Post-close virtual waits return immediately.
        c.sleep_sim(1_000_000);
        c.tick();
    }

    #[test]
    fn auto_mode_advances_through_sleep_sim() {
        let c = Clock::driven_auto();
        let t0 = Instant::now();
        c.sleep_sim(3_600_000); // an hour of cluster life...
        assert_eq!(c.now_ms(), 3_600_000);
        assert!(t0.elapsed() < Duration::from_secs(1), "...in real milliseconds");
        c.tick();
        assert_eq!(c.now_ms(), 3_600_001);
    }

    #[test]
    fn scaled_clock_ignores_driven_surface() {
        let c = Clock::new(100);
        assert!(!c.is_driven());
        c.advance_ms(1_000_000); // no-op
        assert!(c.now_ms() < 1_000_000);
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        // No timer service in scaled mode: nothing registered, nothing
        // fired — callers fall back to sim_to_real timeouts.
        assert!(c
            .notify_at(
                u64::MAX,
                Arc::new(move || {
                    h.fetch_add(1, Ordering::Relaxed);
                })
            )
            .is_none());
        assert_eq!(hits.load(Ordering::Relaxed), 0);
        assert_eq!(c.sim_to_real(1000), Some(Duration::from_micros(10_000)));
        assert_eq!(Clock::driven().sim_to_real(1000), None);
    }
}
