//! Hybrid virtual clock.
//!
//! Real compute (PJRT executions, query processing) takes the time it
//! takes; *declared* durations (a job that "runs for 10 minutes") are
//! compressed by `scale`. `now_ms` advances with real time multiplied by
//! the scale, so queueing dynamics (time limits, backfill windows)
//! behave like the paper's wall-clock while tests stay fast.

use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone)]
pub struct Clock {
    start: Arc<Instant>,
    scale: u64,
}

impl Clock {
    pub fn new(scale: u64) -> Clock {
        Clock { start: Arc::new(Instant::now()), scale: scale.max(1) }
    }

    /// Simulated milliseconds since cluster boot.
    pub fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64 * self.scale
    }

    /// Real milliseconds since cluster boot (for perf measurement).
    pub fn real_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Sleep for `sim_ms` simulated milliseconds.
    pub fn sleep_sim(&self, sim_ms: u64) {
        std::thread::sleep(Duration::from_micros(
            (sim_ms * 1000 / self.scale).max(1),
        ));
    }

    /// The scheduler tick: a short real-time pause.
    pub fn tick(&self) {
        std::thread::sleep(Duration::from_millis(1));
    }

    pub fn scale(&self) -> u64 {
        self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_time_advances_faster() {
        let c = Clock::new(50);
        let t0 = c.now_ms();
        std::thread::sleep(Duration::from_millis(20));
        let dt = c.now_ms() - t0;
        assert!(dt >= 500, "expected >=500 sim ms, got {dt}");
    }

    #[test]
    fn sleep_sim_compresses() {
        let c = Clock::new(100);
        let t0 = Instant::now();
        c.sleep_sim(1000); // 1 simulated second ~ 10 real ms
        assert!(t0.elapsed() < Duration::from_millis(200));
    }
}
