//! Node model: capacity, allocations, health.

use std::collections::HashMap;

/// Allocatable capacity of a node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Resources {
    pub cpus: u32,
    pub memory_bytes: u64,
}

/// Node health, Slurm-style.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    Up,
    Down,
    Drain,
}

/// A compute node with per-job allocations.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub resources: Resources,
    pub state: NodeState,
    /// job id -> (cpus, memory) currently allocated.
    allocations: HashMap<u64, (u32, u64)>,
}

impl Node {
    pub fn new(name: &str, cpus: u32, memory_bytes: u64) -> Node {
        Node {
            name: name.to_string(),
            resources: Resources { cpus, memory_bytes },
            state: NodeState::Up,
            allocations: HashMap::new(),
        }
    }

    pub fn free_cpus(&self) -> u32 {
        let used: u32 = self.allocations.values().map(|(c, _)| *c).sum();
        self.resources.cpus.saturating_sub(used)
    }

    pub fn free_memory(&self) -> u64 {
        let used: u64 = self.allocations.values().map(|(_, m)| *m).sum();
        self.resources.memory_bytes.saturating_sub(used)
    }

    pub fn can_fit(&self, cpus: u32, memory: u64) -> bool {
        self.is_schedulable() && self.free_cpus() >= cpus && self.free_memory() >= memory
    }

    /// Whether the scheduler may reserve on this node at all (`Up`;
    /// `Drain`/`Down` nodes keep allocations but accept no new ones).
    pub fn is_schedulable(&self) -> bool {
        self.state == NodeState::Up
    }

    /// Reserve resources for a job. Returns false (no change) if they
    /// don't fit.
    pub fn allocate(&mut self, job: u64, cpus: u32, memory: u64) -> bool {
        if !self.can_fit(cpus, memory) {
            return false;
        }
        let entry = self.allocations.entry(job).or_insert((0, 0));
        entry.0 += cpus;
        entry.1 += memory;
        true
    }

    /// Release a job's resources (idempotent). Returns what was freed
    /// — `(cpus, memory)` — so a capacity index can be maintained
    /// incrementally; `None` means the job held nothing here.
    pub fn release(&mut self, job: u64) -> Option<(u32, u64)> {
        self.allocations.remove(&job)
    }

    pub fn job_ids(&self) -> Vec<u64> {
        self.allocations.keys().copied().collect()
    }

    pub fn is_idle(&self) -> bool {
        self.allocations.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release() {
        let mut n = Node::new("n1", 8, 16 << 30);
        assert!(n.allocate(1, 4, 8 << 30));
        assert_eq!(n.free_cpus(), 4);
        assert!(!n.allocate(2, 5, 1 << 30), "over-cpu must fail");
        assert!(n.allocate(2, 4, 8 << 30));
        assert_eq!(n.free_cpus(), 0);
        assert_eq!(n.free_memory(), 0);
        n.release(1);
        assert_eq!(n.free_cpus(), 4);
        n.release(1); // idempotent
        assert_eq!(n.free_cpus(), 4);
    }

    #[test]
    fn down_node_rejects() {
        let mut n = Node::new("n1", 8, 16 << 30);
        n.state = NodeState::Down;
        assert!(!n.allocate(1, 1, 1));
    }

    #[test]
    fn same_job_accumulates() {
        let mut n = Node::new("n1", 8, 16 << 30);
        assert!(n.allocate(1, 2, 1 << 30));
        assert!(n.allocate(1, 2, 1 << 30));
        assert_eq!(n.free_cpus(), 4);
        n.release(1);
        assert_eq!(n.free_cpus(), 8);
    }
}
