//! HPC cluster hardware model: nodes, resources, virtual time, failures.
//!
//! Stands in for the AWS ParallelCluster testbed of SS4. The Slurm
//! simulator allocates against these nodes; the Apptainer runtime "runs"
//! containers on them; Flannel hands out per-node pod subnets.
//!
//! # Time model
//!
//! [`Clock`] is the single source of time for the whole control plane
//! — every timeout, TTL, backstop, cron schedule and load curve is
//! measured in *simulated* ms on it. A clock is either **scaled**
//! (sim time = real time × [`ClusterSpec::time_scale`]) or **driven**
//! (`time_scale: 0` / [`Clock::driven`]: frozen until
//! [`Clock::advance_ms`], waking registered waiters in strict deadline
//! order — the deterministic-replay mode). The full contract, including
//! which APIs are deadline-safe against a frozen clock, is documented
//! in [`clock`]; `docs/TIME.md` has a worked replay example.

pub mod clock;
mod node;

pub use clock::{Clock, TimerId, TimerWaker};
pub use node::{Node, NodeState, Resources};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Static description of one node type.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    pub cpus: u32,
    pub memory_bytes: u64,
}

/// Cluster-wide configuration (paper SS4: login node + compute nodes).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub nodes: Vec<NodeSpec>,
    /// Virtual-time scale: how many simulated milliseconds elapse per
    /// real millisecond of sleeping (compute work always runs for
    /// real). `0` selects a **driven** clock ([`Clock::driven`]): time
    /// is frozen until the harness calls [`Clock::advance_ms`] — the
    /// deterministic-replay mode (see [`clock`]'s *Time model*).
    pub time_scale: u64,
}

impl ClusterSpec {
    /// A uniform cluster of `n` nodes with `cpus` cores each.
    pub fn uniform(n: usize, cpus: u32, memory_gib: u64) -> ClusterSpec {
        ClusterSpec {
            name: "hpc".to_string(),
            nodes: (0..n)
                .map(|i| NodeSpec {
                    name: format!("node{:02}", i + 1),
                    cpus,
                    memory_bytes: memory_gib << 30,
                })
                .collect(),
            time_scale: 100,
        }
    }

    /// Switch to a driven clock (`time_scale = 0`): the cluster's time
    /// moves only when the harness advances it.
    pub fn driven(mut self) -> ClusterSpec {
        self.time_scale = 0;
        self
    }
}

/// The simulated cluster: shared node table + clock.
///
/// The node table carries an *epoch*: a counter bumped by every
/// mutation made through [`Cluster::with_nodes`] (failure injection,
/// test surgery, anything outside the scheduler). The scheduler's
/// [`crate::slurm::CapacityIndex`] keys its cached free-capacity
/// buckets on it — a matching epoch means the table only changed
/// through the index itself, so the buckets are still exact.
#[derive(Clone)]
pub struct Cluster {
    pub clock: Clock,
    nodes: Arc<Mutex<Vec<Node>>>,
    epoch: Arc<AtomicU64>,
    pub spec: ClusterSpec,
}

impl Cluster {
    pub fn new(spec: ClusterSpec) -> Cluster {
        let nodes = spec
            .nodes
            .iter()
            .map(|ns| Node::new(&ns.name, ns.cpus, ns.memory_bytes))
            .collect();
        let clock = if spec.time_scale == 0 {
            Clock::driven()
        } else {
            Clock::new(spec.time_scale)
        };
        Cluster {
            clock,
            nodes: Arc::new(Mutex::new(nodes)),
            epoch: Arc::new(AtomicU64::new(1)),
            spec,
        }
    }

    /// Run `f` with the node table locked for mutation. Bumps the
    /// epoch (while still holding the lock), invalidating any capacity
    /// index built against the previous table.
    pub fn with_nodes<R>(&self, f: impl FnOnce(&mut Vec<Node>) -> R) -> R {
        let mut nodes = self.nodes.lock().unwrap();
        let r = f(&mut nodes);
        self.epoch.fetch_add(1, Ordering::SeqCst);
        r
    }

    /// Run `f` with the node table locked, read-only: no epoch bump.
    pub fn with_nodes_ref<R>(&self, f: impl FnOnce(&[Node]) -> R) -> R {
        let nodes = self.nodes.lock().unwrap();
        f(&nodes)
    }

    /// Mutate the node table *without* bumping the epoch — reserved
    /// for the scheduler, whose capacity index mirrors every change it
    /// makes (see [`crate::slurm::CapacityView`]).
    pub(crate) fn with_nodes_untracked<R>(&self, f: impl FnOnce(&mut Vec<Node>) -> R) -> R {
        let mut nodes = self.nodes.lock().unwrap();
        f(&mut nodes)
    }

    /// The current node-table epoch (see the type docs).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    pub fn node_names(&self) -> Vec<String> {
        self.with_nodes_ref(|ns| ns.iter().map(|n| n.name.clone()).collect())
    }

    /// Total and free CPU across up nodes.
    pub fn cpu_summary(&self) -> (u32, u32) {
        self.with_nodes_ref(|ns| {
            let mut total = 0;
            let mut free = 0;
            for n in ns.iter() {
                if n.state == NodeState::Up {
                    total += n.resources.cpus;
                    free += n.free_cpus();
                }
            }
            (total, free)
        })
    }

    /// Mark a node down (failure injection); returns false if unknown.
    pub fn fail_node(&self, name: &str) -> bool {
        self.with_nodes(|ns| {
            for n in ns.iter_mut() {
                if n.name == name {
                    n.state = NodeState::Down;
                    return true;
                }
            }
            false
        })
    }

    /// Bring a failed node back.
    pub fn restore_node(&self, name: &str) -> bool {
        self.with_nodes(|ns| {
            for n in ns.iter_mut() {
                if n.name == name {
                    n.state = NodeState::Up;
                    return true;
                }
            }
            false
        })
    }

    /// Alias for [`Cluster::restore_node`] — the chaos-harness vocabulary
    /// pairs `fail_node`/`recover_node`.
    pub fn recover_node(&self, name: &str) -> bool {
        self.restore_node(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_cluster_shape() {
        let c = Cluster::new(ClusterSpec::uniform(4, 16, 64));
        assert_eq!(c.node_names().len(), 4);
        let (total, free) = c.cpu_summary();
        assert_eq!(total, 64);
        assert_eq!(free, 64);
    }

    #[test]
    fn failing_a_node_removes_capacity() {
        let c = Cluster::new(ClusterSpec::uniform(2, 8, 16));
        assert!(c.fail_node("node01"));
        let (total, _) = c.cpu_summary();
        assert_eq!(total, 8);
        assert!(c.restore_node("node01"));
        assert_eq!(c.cpu_summary().0, 16);
        assert!(!c.fail_node("nope"));
        assert!(c.recover_node("node01") && !c.recover_node("nope"));
    }

    /// Every node-state mutation must bump the epoch, so an
    /// epoch-keyed capacity index rebuilt right after `fail_node`
    /// refuses the dead node immediately (no stale free-CPU buckets).
    #[test]
    fn fail_and_recover_bump_epoch_and_invalidate_capacity() {
        use crate::slurm::{CapacityIndex, CapacityView};
        let c = Cluster::new(ClusterSpec::uniform(1, 4, 8));
        let mut index = CapacityIndex::new();
        c.with_nodes_untracked(|nodes| {
            let mut view = CapacityView::new(&mut index, nodes, 1);
            assert!(view.reserve(1, 1, 0).is_some());
        });
        let before = c.epoch();
        assert!(c.fail_node("node01"));
        assert!(c.epoch() > before, "fail_node must bump the epoch");
        c.with_nodes_untracked(|nodes| {
            let mut view = CapacityView::new(&mut index, nodes, c.epoch());
            assert!(
                view.reserve(2, 1, 0).is_none(),
                "down node must be refused immediately after fail_node"
            );
        });
        let before = c.epoch();
        assert!(c.recover_node("node01"));
        assert!(c.epoch() > before, "recover_node must bump the epoch");
        c.with_nodes_untracked(|nodes| {
            let mut view = CapacityView::new(&mut index, nodes, c.epoch());
            assert!(view.reserve(3, 1, 0).is_some(), "recovered node schedulable");
        });
    }
}
