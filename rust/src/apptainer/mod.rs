//! Singularity/Apptainer container-runtime simulator + Flannel CNI.
//!
//! HPK executes every pod as Apptainer container instances inside a
//! Slurm job (SS3). The runtime features HPK relies on, all reproduced
//! here at the interface level:
//!
//! - **image handling** — a registry of image references whose
//!   "entrypoints" are Rust closures (our stand-in for container
//!   payloads), with one-time per-node pull latency ([`ImageRegistry`]).
//! - **fakeroot** — the configuration HPK requires so Docker images that
//!   assume uid 0 run unprivileged; enforced as a per-runtime capability
//!   bit, and containers that declare `needs_root` fail without it.
//! - **CNI networking** — Apptainer delegates pod addressing to a
//!   cluster-wide Flannel: per-node `/24` subnets under `10.244.0.0/16`
//!   ([`Flannel`]).
//! - **pod network topology** — hpk-kubelet's parent/child embedding:
//!   the *parent* container owns the pod IP; child containers join its
//!   network context and share `localhost` ([`NetContext`]).
//! - **a connection fabric** — [`NetFabric`] binds `(ip, port)` pairs to
//!   in-process service endpoints so that DNS-resolved addresses are
//!   actually connectable (how MinIO, parameter servers and inference
//!   services talk in the reproduction).

mod cni;
mod fabric;
mod image;
mod runtime;

pub use cni::Flannel;
pub use fabric::NetFabric;
pub use image::{ImageRegistry, ImageSpec};
pub use runtime::{
    ApptainerRuntime, ContainerCtx, Entrypoint, EntrypointTable, NetContext,
    ServiceHub,
};
