//! Flannel-style CNI: per-node /24 pod subnets under 10.244.0.0/16.
//!
//! The paper's evaluation installs "Apptainer with the Flannel CNI
//! plugin ... to distribute private IPs to container instances and
//! manage routes across nodes" (SS4). This reproduces the allocation
//! semantics: each node gets a disjoint /24; pod IPs are unique
//! cluster-wide; releasing an IP makes it reusable.

use std::collections::HashMap;
use std::net::Ipv4Addr;
use std::sync::Mutex;

struct NodeSubnet {
    subnet: u8,
    /// Host-part usage bitmap, indices 2..=254 usable (.0 net, .1
    /// gateway, .255 broadcast).
    used: [bool; 256],
}

/// Cluster-wide IP allocator.
pub struct Flannel {
    base: (u8, u8),
    inner: Mutex<FlannelInner>,
}

#[derive(Default)]
struct FlannelInner {
    nodes: HashMap<String, NodeSubnet>,
    next_subnet: u8,
}

impl Default for Flannel {
    fn default() -> Flannel {
        Flannel::new()
    }
}

impl Flannel {
    /// The conventional flannel pod CIDR 10.244.0.0/16.
    pub fn new() -> Flannel {
        Flannel { base: (10, 244), inner: Mutex::new(FlannelInner::default()) }
    }

    /// Allocate a pod IP on `node`, registering the node's subnet on
    /// first use. Returns `None` when the node's /24 (253 pods) or the
    /// /16 (256 nodes) is exhausted.
    pub fn allocate(&self, node: &str) -> Option<Ipv4Addr> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.nodes.contains_key(node) {
            let subnet = inner.next_subnet;
            inner.next_subnet = inner.next_subnet.checked_add(1)?;
            inner.nodes.insert(
                node.to_string(),
                NodeSubnet { subnet, used: [false; 256] },
            );
        }
        let ns = inner.nodes.get_mut(node).unwrap();
        for host in 2..=254u16 {
            if !ns.used[host as usize] {
                ns.used[host as usize] = true;
                return Some(Ipv4Addr::new(
                    self.base.0,
                    self.base.1,
                    ns.subnet,
                    host as u8,
                ));
            }
        }
        None
    }

    /// Release a previously allocated IP (idempotent).
    pub fn release(&self, ip: Ipv4Addr) {
        let [a, b, subnet, host] = ip.octets();
        if (a, b) != self.base {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        for ns in inner.nodes.values_mut() {
            if ns.subnet == subnet {
                ns.used[host as usize] = false;
                return;
            }
        }
    }

    /// The /24 assigned to a node, if registered.
    pub fn node_subnet(&self, node: &str) -> Option<Ipv4Addr> {
        let inner = self.inner.lock().unwrap();
        inner
            .nodes
            .get(node)
            .map(|ns| Ipv4Addr::new(self.base.0, self.base.1, ns.subnet, 0))
    }

    /// Number of live allocations (for leak tests).
    pub fn live_count(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner
            .nodes
            .values()
            .map(|ns| ns.used.iter().filter(|u| **u).count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_node_subnets_disjoint() {
        let f = Flannel::new();
        let a = f.allocate("n1").unwrap();
        let b = f.allocate("n2").unwrap();
        assert_ne!(a.octets()[2], b.octets()[2]);
        assert_eq!(f.node_subnet("n1").unwrap().octets()[3], 0);
    }

    #[test]
    fn ips_unique_and_reusable() {
        let f = Flannel::new();
        let a = f.allocate("n1").unwrap();
        let b = f.allocate("n1").unwrap();
        assert_ne!(a, b);
        f.release(a);
        let c = f.allocate("n1").unwrap();
        assert_eq!(a, c, "released IP is reused first");
    }

    #[test]
    fn subnet_exhaustion() {
        let f = Flannel::new();
        let mut got = Vec::new();
        for _ in 0..253 {
            got.push(f.allocate("n1").unwrap());
        }
        assert!(f.allocate("n1").is_none());
        f.release(got[100]);
        assert!(f.allocate("n1").is_some());
    }

    #[test]
    fn release_foreign_ip_is_noop() {
        let f = Flannel::new();
        f.release(Ipv4Addr::new(192, 168, 1, 1));
        assert_eq!(f.live_count(), 0);
    }
}
