//! In-process "network": binds (ip, port) to service endpoint objects.
//!
//! The reproduction has no real sockets; services (MinIO, inference
//! servers, Spark drivers) bind typed endpoint objects here, and clients
//! that resolved a pod IP through CoreDNS connect by address. This keeps
//! the paper's service-discovery semantics observable: a headless
//! service only works if DNS hands out pod IPs that are actually bound.

use std::any::Any;
use std::collections::HashMap;
use std::net::Ipv4Addr;
use std::sync::{Arc, Mutex};

type Endpoint = Arc<dyn Any + Send + Sync>;

/// Cluster-wide endpoint table; cheap to clone.
#[derive(Clone, Default)]
pub struct NetFabric {
    inner: Arc<Mutex<HashMap<(Ipv4Addr, u16), Endpoint>>>,
}

impl NetFabric {
    pub fn new() -> NetFabric {
        NetFabric::default()
    }

    /// Bind a service object at `(ip, port)`. Returns false if the
    /// address is already bound (EADDRINUSE).
    pub fn bind<T: Any + Send + Sync>(
        &self,
        ip: Ipv4Addr,
        port: u16,
        service: Arc<T>,
    ) -> bool {
        let mut map = self.inner.lock().unwrap();
        if map.contains_key(&(ip, port)) {
            return false;
        }
        map.insert((ip, port), service);
        true
    }

    /// Connect to `(ip, port)`, downcasting to the expected service type.
    pub fn connect<T: Any + Send + Sync>(
        &self,
        ip: Ipv4Addr,
        port: u16,
    ) -> Option<Arc<T>> {
        let map = self.inner.lock().unwrap();
        map.get(&(ip, port)).cloned()?.downcast::<T>().ok()
    }

    /// Whether anything is bound at the address (port probe).
    pub fn is_bound(&self, ip: Ipv4Addr, port: u16) -> bool {
        self.inner.lock().unwrap().contains_key(&(ip, port))
    }

    /// Remove a binding (idempotent). All bindings for an IP can be
    /// cleared when its pod dies via [`NetFabric::unbind_ip`].
    pub fn unbind(&self, ip: Ipv4Addr, port: u16) {
        self.inner.lock().unwrap().remove(&(ip, port));
    }

    /// Drop every port bound on `ip` (pod teardown).
    pub fn unbind_ip(&self, ip: Ipv4Addr) {
        self.inner.lock().unwrap().retain(|(bip, _), _| *bip != ip);
    }

    pub fn bound_count(&self) -> usize {
        self.inner.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo(&'static str);

    #[test]
    fn bind_connect_typed() {
        let fab = NetFabric::new();
        let ip = Ipv4Addr::new(10, 244, 0, 2);
        assert!(fab.bind(ip, 9000, Arc::new(Echo("minio"))));
        let svc: Arc<Echo> = fab.connect(ip, 9000).unwrap();
        assert_eq!(svc.0, "minio");
        // Wrong type downcasts to None.
        assert!(fab.connect::<String>(ip, 9000).is_none());
        // Wrong port.
        assert!(fab.connect::<Echo>(ip, 9001).is_none());
    }

    #[test]
    fn double_bind_rejected() {
        let fab = NetFabric::new();
        let ip = Ipv4Addr::new(10, 244, 0, 2);
        assert!(fab.bind(ip, 80, Arc::new(Echo("a"))));
        assert!(!fab.bind(ip, 80, Arc::new(Echo("b"))));
    }

    #[test]
    fn unbind_ip_clears_all_ports() {
        let fab = NetFabric::new();
        let ip = Ipv4Addr::new(10, 244, 0, 3);
        fab.bind(ip, 1, Arc::new(Echo("x")));
        fab.bind(ip, 2, Arc::new(Echo("y")));
        fab.bind(Ipv4Addr::new(10, 244, 0, 4), 1, Arc::new(Echo("z")));
        fab.unbind_ip(ip);
        assert_eq!(fab.bound_count(), 1);
    }
}
