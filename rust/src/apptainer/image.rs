//! Container image registry with per-node pull cache.

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

use crate::hpcsim::Clock;

/// A registered image. The `entrypoint_key` selects the Rust closure in
/// [`super::EntrypointTable`] that simulates the container's payload
/// (when `args` don't override it).
#[derive(Debug, Clone)]
pub struct ImageSpec {
    /// Full reference, e.g. `minio/minio:latest`.
    pub reference: String,
    /// Key into the entrypoint table.
    pub entrypoint_key: String,
    /// Image-baked environment (overridable per container).
    pub env: Vec<(String, String)>,
    /// Compressed size; drives the simulated first-pull latency.
    pub size_bytes: u64,
    /// Whether the payload assumes uid 0 (common Docker images); such
    /// images require the runtime's fakeroot capability.
    pub needs_root: bool,
}

impl ImageSpec {
    pub fn new(reference: &str, entrypoint_key: &str) -> ImageSpec {
        ImageSpec {
            reference: reference.to_string(),
            entrypoint_key: entrypoint_key.to_string(),
            env: Vec::new(),
            size_bytes: 50 << 20,
            needs_root: false,
        }
    }

    pub fn with_env(mut self, k: &str, v: &str) -> ImageSpec {
        self.env.push((k.to_string(), v.to_string()));
        self
    }

    pub fn with_size(mut self, bytes: u64) -> ImageSpec {
        self.size_bytes = bytes;
        self
    }

    pub fn root(mut self) -> ImageSpec {
        self.needs_root = true;
        self
    }
}

/// Image store + per-node pulled cache.
#[derive(Default)]
pub struct ImageRegistry {
    images: Mutex<HashMap<String, ImageSpec>>,
    pulled: Mutex<HashSet<(String, String)>>, // (node, reference)
}

/// Simulated pull throughput: bytes per simulated millisecond.
const PULL_BYTES_PER_SIM_MS: u64 = 10 << 20;

impl ImageRegistry {
    pub fn new() -> ImageRegistry {
        ImageRegistry::default()
    }

    pub fn register(&self, spec: ImageSpec) {
        self.images
            .lock()
            .unwrap()
            .insert(spec.reference.clone(), spec);
    }

    /// Resolve a reference; `name` (no tag) falls back to `name:latest`.
    pub fn resolve(&self, reference: &str) -> Option<ImageSpec> {
        let images = self.images.lock().unwrap();
        images.get(reference).cloned().or_else(|| {
            if reference.contains(':') {
                None
            } else {
                images.get(&format!("{reference}:latest")).cloned()
            }
        })
    }

    /// Ensure the image is present on `node`, paying the simulated pull
    /// cost on first use (Apptainer's SIF cache behaviour).
    pub fn ensure_pulled(
        &self,
        node: &str,
        reference: &str,
        clock: &Clock,
    ) -> Result<ImageSpec, String> {
        let spec = self
            .resolve(reference)
            .ok_or_else(|| format!("image not found: {reference}"))?;
        let key = (node.to_string(), spec.reference.clone());
        {
            let pulled = self.pulled.lock().unwrap();
            if pulled.contains(&key) {
                return Ok(spec);
            }
        }
        // Pull outside the lock; mark afterwards (duplicate pulls are
        // harmless, like concurrent `apptainer pull`s).
        clock.sleep_sim(spec.size_bytes / PULL_BYTES_PER_SIM_MS);
        self.pulled.lock().unwrap().insert(key);
        Ok(spec)
    }

    /// Whether a node already has the image (no pull cost).
    pub fn is_pulled(&self, node: &str, reference: &str) -> bool {
        self.pulled
            .lock()
            .unwrap()
            .contains(&(node.to_string(), reference.to_string()))
    }

    pub fn image_count(&self) -> usize {
        self.images.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_resolve_latest_fallback() {
        let reg = ImageRegistry::new();
        reg.register(ImageSpec::new("busybox:latest", "busybox"));
        assert!(reg.resolve("busybox:latest").is_some());
        assert!(reg.resolve("busybox").is_some());
        assert!(reg.resolve("busybox:1.0").is_none());
        assert!(reg.resolve("nginx").is_none());
    }

    #[test]
    fn pull_cached_per_node() {
        let reg = ImageRegistry::new();
        reg.register(ImageSpec::new("a:1", "a").with_size(1 << 20));
        let clock = Clock::new(1000);
        assert!(!reg.is_pulled("n1", "a:1"));
        reg.ensure_pulled("n1", "a:1", &clock).unwrap();
        assert!(reg.is_pulled("n1", "a:1"));
        assert!(!reg.is_pulled("n2", "a:1"));
        reg.ensure_pulled("n2", "a:1", &clock).unwrap();
        assert!(reg.is_pulled("n2", "a:1"));
    }

    #[test]
    fn missing_image_errors() {
        let reg = ImageRegistry::new();
        let clock = Clock::new(1000);
        assert!(reg.ensure_pulled("n1", "ghost:9", &clock).is_err());
    }

    #[test]
    fn builder_flags() {
        let s = ImageSpec::new("x:1", "x").with_env("A", "1").root();
        assert!(s.needs_root);
        assert_eq!(s.env[0].0, "A");
    }
}
