//! The container runtime: pod sandboxes, fakeroot, entrypoint dispatch.

use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::net::Ipv4Addr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::{Flannel, ImageRegistry, NetFabric};
use crate::hpcsim::Clock;
use crate::slurm::CancelToken;
use crate::virtfs::VirtFs;

/// A pod's shared network context: the "parent" container owns the IP,
/// children join it (the paper's embedded-container topology).
#[derive(Debug, Clone)]
pub struct NetContext {
    pub ip: Ipv4Addr,
    pub node: String,
    /// Sandbox id (parent instance id).
    pub sandbox_id: u64,
}

/// Type-map of in-process services available to entrypoints (the PJRT
/// runtime, object-store handles, the kube API client for operators...).
#[derive(Clone, Default)]
pub struct ServiceHub {
    map: Arc<Mutex<HashMap<TypeId, Arc<dyn Any + Send + Sync>>>>,
}

impl ServiceHub {
    pub fn new() -> ServiceHub {
        ServiceHub::default()
    }

    pub fn insert<T: Any + Send + Sync>(&self, svc: Arc<T>) {
        self.map.lock().unwrap().insert(TypeId::of::<T>(), svc);
    }

    pub fn get<T: Any + Send + Sync>(&self) -> Option<Arc<T>> {
        self.map
            .lock()
            .unwrap()
            .get(&TypeId::of::<T>())
            .cloned()?
            .downcast::<T>()
            .ok()
    }

    /// Like `get`, but with a workload-friendly error message.
    pub fn expect<T: Any + Send + Sync>(&self, what: &str) -> Result<Arc<T>, String> {
        self.get::<T>()
            .ok_or_else(|| format!("service not available in hub: {what}"))
    }
}

/// Everything an entrypoint closure sees — the container's world.
pub struct ContainerCtx {
    /// Image reference that launched this container.
    pub image: String,
    /// Command + args (entrypoint override when non-empty).
    pub args: Vec<String>,
    pub env: HashMap<String, String>,
    /// Pod IP (shared with siblings in the same sandbox).
    pub ip: Ipv4Addr,
    pub node: String,
    pub fs: VirtFs,
    pub fabric: NetFabric,
    pub cancel: CancelToken,
    pub clock: Clock,
    pub hub: ServiceHub,
}

impl ContainerCtx {
    pub fn env_or(&self, key: &str, default: &str) -> String {
        self.env.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn env_parsed<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.env.get(key).and_then(|v| v.parse().ok())
    }
}

/// A container payload: returns the exit code.
pub type Entrypoint = Arc<dyn Fn(&ContainerCtx) -> Result<i32, String> + Send + Sync>;

/// Entrypoint registry, keyed by the image's `entrypoint_key`.
#[derive(Clone, Default)]
pub struct EntrypointTable {
    map: Arc<Mutex<HashMap<String, Entrypoint>>>,
}

impl EntrypointTable {
    pub fn new() -> EntrypointTable {
        EntrypointTable::default()
    }

    pub fn register<F>(&self, key: &str, f: F)
    where
        F: Fn(&ContainerCtx) -> Result<i32, String> + Send + Sync + 'static,
    {
        self.map.lock().unwrap().insert(key.to_string(), Arc::new(f));
    }

    pub fn get(&self, key: &str) -> Option<Entrypoint> {
        self.map.lock().unwrap().get(key).cloned()
    }
}

/// The per-cluster Apptainer runtime.
pub struct ApptainerRuntime {
    pub registry: ImageRegistry,
    pub table: EntrypointTable,
    pub cni: Flannel,
    pub fabric: NetFabric,
    pub fs: VirtFs,
    pub clock: Clock,
    pub hub: ServiceHub,
    /// Host-level configuration: whether admins enabled fakeroot (one of
    /// the two host changes HPK requires, SS3).
    pub fakeroot_allowed: bool,
    next_id: AtomicU64,
    running: Mutex<HashMap<u64, String>>, // instance id -> image
}

impl ApptainerRuntime {
    pub fn new(fs: VirtFs, clock: Clock, fakeroot_allowed: bool) -> ApptainerRuntime {
        ApptainerRuntime {
            registry: ImageRegistry::new(),
            table: EntrypointTable::new(),
            cni: Flannel::new(),
            fabric: NetFabric::new(),
            fs,
            clock,
            hub: ServiceHub::new(),
            fakeroot_allowed,
            next_id: AtomicU64::new(1),
            running: Mutex::new(HashMap::new()),
        }
    }

    /// Start a pod sandbox on `node`: allocates the pod IP via CNI and
    /// creates the parent network context.
    pub fn create_sandbox(&self, node: &str) -> Result<NetContext, String> {
        let ip = self
            .cni
            .allocate(node)
            .ok_or_else(|| format!("flannel: subnet exhausted on {node}"))?;
        Ok(NetContext {
            ip,
            node: node.to_string(),
            sandbox_id: self.next_id.fetch_add(1, Ordering::Relaxed),
        })
    }

    /// Tear down a sandbox: release the IP and all fabric bindings.
    pub fn destroy_sandbox(&self, net: &NetContext) {
        self.fabric.unbind_ip(net.ip);
        self.cni.release(net.ip);
    }

    /// Run one container synchronously inside a sandbox ("child"
    /// containers share the sandbox's network context). Blocks until
    /// the entrypoint returns; a non-zero exit code is an `Err`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_container(
        &self,
        net: &NetContext,
        image_ref: &str,
        args: &[String],
        env: &[(String, String)],
        fakeroot: bool,
        cancel: CancelToken,
    ) -> Result<(), String> {
        let spec = self
            .registry
            .ensure_pulled(&net.node, image_ref, &self.clock)?;
        if spec.needs_root && !fakeroot {
            return Err(format!(
                "image {image_ref} requires root; run with fakeroot"
            ));
        }
        if fakeroot && !self.fakeroot_allowed {
            return Err(
                "fakeroot not permitted by host configuration (ask your \
                 HPC admins to enable it in apptainer.conf)"
                    .to_string(),
            );
        }
        let entry = self.table.get(&spec.entrypoint_key).ok_or_else(|| {
            format!("no entrypoint registered for key {}", spec.entrypoint_key)
        })?;
        let mut env_map: HashMap<String, String> =
            spec.env.iter().cloned().collect();
        for (k, v) in env {
            env_map.insert(k.clone(), v.clone());
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.running
            .lock()
            .unwrap()
            .insert(id, spec.reference.clone());
        let ctx = ContainerCtx {
            image: spec.reference.clone(),
            args: args.to_vec(),
            env: env_map,
            ip: net.ip,
            node: net.node.clone(),
            fs: self.fs.clone(),
            fabric: self.fabric.clone(),
            cancel,
            clock: self.clock.clone(),
            hub: self.hub.clone(),
        };
        let result = entry(&ctx);
        self.running.lock().unwrap().remove(&id);
        match result {
            Ok(0) => Ok(()),
            Ok(code) => Err(format!("container exited with code {code}")),
            Err(e) => Err(e),
        }
    }

    /// Number of currently executing containers (instance list).
    pub fn instance_count(&self) -> usize {
        self.running.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apptainer::ImageSpec;

    fn runtime() -> ApptainerRuntime {
        let rt = ApptainerRuntime::new(VirtFs::new(), Clock::new(1000), true);
        rt.registry.register(ImageSpec::new("echo:latest", "echo"));
        rt.registry
            .register(ImageSpec::new("rooty:latest", "echo").root());
        rt.table.register("echo", |ctx| {
            ctx.fs
                .write_str("/out/echo.txt", &ctx.args.join(" "))
                .map_err(|e| e.to_string())?;
            Ok(0)
        });
        rt
    }

    #[test]
    fn sandbox_run_teardown() {
        let rt = runtime();
        let net = rt.create_sandbox("n1").unwrap();
        rt.run_container(
            &net,
            "echo:latest",
            &["hello".to_string(), "world".to_string()],
            &[],
            false,
            CancelToken::new(),
        )
        .unwrap();
        assert_eq!(rt.fs.read_str("/out/echo.txt").unwrap(), "hello world");
        rt.destroy_sandbox(&net);
        assert_eq!(rt.cni.live_count(), 0);
    }

    #[test]
    fn root_image_needs_fakeroot() {
        let rt = runtime();
        let net = rt.create_sandbox("n1").unwrap();
        let err = rt
            .run_container(&net, "rooty:latest", &[], &[], false, CancelToken::new())
            .unwrap_err();
        assert!(err.contains("requires root"));
        rt.run_container(&net, "rooty:latest", &[], &[], true, CancelToken::new())
            .unwrap();
    }

    #[test]
    fn fakeroot_requires_host_opt_in() {
        let rt = ApptainerRuntime::new(VirtFs::new(), Clock::new(1000), false);
        rt.registry.register(ImageSpec::new("x:1", "x"));
        rt.table.register("x", |_| Ok(0));
        let net = rt.create_sandbox("n1").unwrap();
        let err = rt
            .run_container(&net, "x:1", &[], &[], true, CancelToken::new())
            .unwrap_err();
        assert!(err.contains("not permitted"));
    }

    #[test]
    fn env_layering_image_then_overrides() {
        let rt = ApptainerRuntime::new(VirtFs::new(), Clock::new(1000), true);
        rt.registry
            .register(ImageSpec::new("envy:1", "envy").with_env("A", "img").with_env("B", "img"));
        rt.table.register("envy", |ctx| {
            assert_eq!(ctx.env.get("A").unwrap(), "pod");
            assert_eq!(ctx.env.get("B").unwrap(), "img");
            Ok(0)
        });
        let net = rt.create_sandbox("n1").unwrap();
        rt.run_container(
            &net,
            "envy:1",
            &[],
            &[("A".to_string(), "pod".to_string())],
            false,
            CancelToken::new(),
        )
        .unwrap();
    }

    #[test]
    fn nonzero_exit_is_error() {
        let rt = ApptainerRuntime::new(VirtFs::new(), Clock::new(1000), true);
        rt.registry.register(ImageSpec::new("fail:1", "fail"));
        rt.table.register("fail", |_| Ok(3));
        let net = rt.create_sandbox("n1").unwrap();
        let err = rt
            .run_container(&net, "fail:1", &[], &[], false, CancelToken::new())
            .unwrap_err();
        assert!(err.contains("code 3"));
    }

    #[test]
    fn hub_typed_services() {
        let hub = ServiceHub::new();
        hub.insert(Arc::new(42u64));
        assert_eq!(*hub.get::<u64>().unwrap(), 42);
        assert!(hub.get::<String>().is_none());
        assert!(hub.expect::<String>("thing").is_err());
    }
}
