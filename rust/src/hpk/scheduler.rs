//! The pass-through scheduler.
//!
//! "Since cluster-level scheduling is to be performed by Slurm, HPK
//! employs a custom, simplified pass-through scheduler that makes no
//! scheduling decisions, but always selects hpk-kubelet to run
//! workloads" (SS3). Placement intelligence lives entirely in the Slurm
//! simulator; this controller just binds.
//!
//! Event-driven: it processes only queued Pod keys, so binding cost
//! scales with pod churn, not with the number of objects in the store —
//! and its controller-manager thread blocks on a Pod-kind subscription
//! (push wakeup, no sleep loop), so an idle queue costs nothing.

use crate::kube::controllers::{Context, Reconciler};
use crate::kube::informer::WatchSpec;
use crate::kube::object;
use crate::kube::ListParams;
use crate::yamlkit::Value;

pub struct PassThroughScheduler;

impl Reconciler for PassThroughScheduler {
    fn name(&self) -> &'static str {
        "hpk-scheduler"
    }

    fn watches(&self) -> Vec<WatchSpec> {
        vec![WatchSpec::of("Pod")]
    }

    fn reconcile(&self, ctx: &Context) {
        let pods = ctx.api("Pod");
        // Cached drain: zero-copy snapshots on the hottest path, and
        // pods deleted before we got to them are skipped.
        for (key, pod) in ctx.drain_kind_cached("Pod") {
            if pod.str_at("spec.nodeName").is_some()
                || object::pod_phase(&pod) != "Pending"
            {
                continue;
            }
            // Gang gate: a PodGroup member binds only once every
            // declared member exists in its namespace, so no member
            // reaches Slurm while the group is still materialising.
            // Earlier members' keys were already drained (and skipped),
            // so when the gate finally opens — on the last member's
            // create event — every still-unbound member is bound in
            // the same sweep.
            if let Some(group) =
                object::annotation(&pod, super::annotations::POD_GROUP)
            {
                let size: usize =
                    object::annotation(&pod, super::annotations::POD_GROUP_SIZE)
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(1);
                let members: Vec<_> = pods
                    .list(&ListParams::in_namespace(&key.namespace))
                    .into_iter()
                    .filter(|p| {
                        object::annotation(p, super::annotations::POD_GROUP)
                            == Some(group)
                    })
                    .collect();
                if members.len() < size {
                    continue;
                }
                for m in &members {
                    if m.str_at("spec.nodeName").is_some()
                        || object::pod_phase(m) != "Pending"
                    {
                        continue;
                    }
                    let mut patch = Value::map();
                    patch
                        .entry_map("spec")
                        .set("nodeName", Value::from(super::VIRTUAL_NODE));
                    let _ =
                        pods.patch(&key.namespace, object::name(m), &patch);
                }
                continue;
            }
            let mut patch = Value::map();
            patch
                .entry_map("spec")
                .set("nodeName", Value::from(super::VIRTUAL_NODE));
            let _ = pods.patch(&key.namespace, &key.name, &patch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kube::api::ApiServer;
    use crate::kube::controllers::testutil::reconcile_once;
    use crate::yamlkit::parse_one;

    #[test]
    fn binds_everything_to_virtual_node() {
        let api = ApiServer::new();
        for i in 0..3 {
            api.create(
                parse_one(&format!(
                    "kind: Pod\nmetadata:\n  name: p{i}\nspec:\n  containers:\n  - name: c\n    image: x\n"
                ))
                .unwrap(),
            )
            .unwrap();
        }
        reconcile_once(&api, &PassThroughScheduler);
        for p in api.list("Pod") {
            assert_eq!(p.str_at("spec.nodeName"), Some(super::super::VIRTUAL_NODE));
        }
    }

    #[test]
    fn pod_group_members_bind_only_when_complete() {
        let api = ApiServer::new();
        let member = |i: usize| {
            parse_one(&format!(
                "kind: Pod\nmetadata:\n  name: g{i}\n  annotations:\n    slurm-job.hpk.io/pod-group: ring\n    slurm-job.hpk.io/pod-group-size: \"2\"\nspec:\n  containers:\n  - name: c\n    image: x\n"
            ))
            .unwrap()
        };
        api.create(member(0)).unwrap();
        reconcile_once(&api, &PassThroughScheduler);
        assert!(
            api.get("Pod", "default", "g0").unwrap().str_at("spec.nodeName").is_none(),
            "lone member must wait for the group"
        );
        api.create(member(1)).unwrap();
        reconcile_once(&api, &PassThroughScheduler);
        for name in ["g0", "g1"] {
            assert_eq!(
                api.get("Pod", "default", name).unwrap().str_at("spec.nodeName"),
                Some(super::super::VIRTUAL_NODE),
                "{name} binds once the group is complete"
            );
        }
    }

    #[test]
    fn leaves_bound_and_terminal_pods_alone() {
        let api = ApiServer::new();
        api.create(
            parse_one("kind: Pod\nmetadata:\n  name: done\nspec: {}\nstatus:\n  phase: Succeeded\n")
                .unwrap(),
        )
        .unwrap();
        reconcile_once(&api, &PassThroughScheduler);
        assert!(api
            .get("Pod", "default", "done")
            .unwrap()
            .str_at("spec.nodeName")
            .is_none());
    }
}
