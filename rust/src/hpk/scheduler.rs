//! The pass-through scheduler.
//!
//! "Since cluster-level scheduling is to be performed by Slurm, HPK
//! employs a custom, simplified pass-through scheduler that makes no
//! scheduling decisions, but always selects hpk-kubelet to run
//! workloads" (SS3). Placement intelligence lives entirely in the Slurm
//! simulator; this controller just binds.

use crate::kube::api::ApiServer;
use crate::kube::controllers::Reconciler;
use crate::kube::object;
use crate::yamlkit::Value;

pub struct PassThroughScheduler;

impl Reconciler for PassThroughScheduler {
    fn name(&self) -> &'static str {
        "hpk-scheduler"
    }

    fn reconcile(&self, api: &ApiServer) {
        for pod in api.list_refs("Pod") {
            if pod.str_at("spec.nodeName").is_some()
                || object::pod_phase(&pod) != "Pending"
            {
                continue;
            }
            let mut patch = Value::map();
            patch
                .entry_map("spec")
                .set("nodeName", Value::from(super::VIRTUAL_NODE));
            let _ = api.patch("Pod", object::namespace(&pod), object::name(&pod), &patch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::yamlkit::parse_one;

    #[test]
    fn binds_everything_to_virtual_node() {
        let api = ApiServer::new();
        for i in 0..3 {
            api.create(
                parse_one(&format!(
                    "kind: Pod\nmetadata:\n  name: p{i}\nspec:\n  containers:\n  - name: c\n    image: x\n"
                ))
                .unwrap(),
            )
            .unwrap();
        }
        PassThroughScheduler.reconcile(&api);
        for p in api.list("Pod") {
            assert_eq!(p.str_at("spec.nodeName"), Some(super::super::VIRTUAL_NODE));
        }
    }

    #[test]
    fn leaves_bound_and_terminal_pods_alone() {
        let api = ApiServer::new();
        api.create(
            parse_one("kind: Pod\nmetadata:\n  name: done\nspec: {}\nstatus:\n  phase: Succeeded\n")
                .unwrap(),
        )
        .unwrap();
        PassThroughScheduler.reconcile(&api);
        assert!(api
            .get("Pod", "default", "done")
            .unwrap()
            .str_at("spec.nodeName")
            .is_none());
    }
}
