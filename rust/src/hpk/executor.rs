//! Slurm-side interpreter of hpk-kubelet's generated scripts.
//!
//! Implements [`crate::slurm::JobExecutor`]: when Slurm starts the job,
//! this executor replays the script's `apptainer` lines on the allocated
//! node — starting the pod sandbox (parent container with the CNI-
//! assigned IP), writing the IP handshake file for hpk-kubelet, then
//! running each container. Multi-task jobs (`--ntasks=N` via annotation)
//! run the container once per task slot with `SLURM_PROCID`/
//! `SLURM_NTASKS` set, which is how the paper embeds MPI steps in Argo
//! workflows (Listing 2).

use crate::apptainer::ApptainerRuntime;
use crate::slurm::{JobContext, JobExecutor};
use crate::util::shlex;
use std::sync::Arc;

/// One parsed `apptainer exec` line.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecLine {
    pub image: String,
    pub env: Vec<(String, String)>,
    pub args: Vec<String>,
}

/// Parse the script body into exec lines + the pod dir.
pub fn parse_script_body(body: &str) -> Result<(Option<String>, Vec<ExecLine>), String> {
    let mut pod_dir = None;
    let mut lines = Vec::new();
    for raw in body.lines() {
        let line = raw.trim();
        if let Some(dir) = line.strip_prefix("hpk_pod_dir=") {
            pod_dir = Some(dir.to_string());
            continue;
        }
        if !line.starts_with("apptainer exec") {
            continue;
        }
        let tokens = shlex::split(line)
            .ok_or_else(|| format!("unparsable script line: {line}"))?;
        // apptainer exec instance://parent [--fakeroot] [--env K=V]... image args...
        let mut env = Vec::new();
        let mut rest: Vec<String> = Vec::new();
        let mut i = 2; // skip "apptainer exec"
        while i < tokens.len() {
            match tokens[i].as_str() {
                "--fakeroot" => {}
                t if t.starts_with("instance://") => {}
                "--env" => {
                    i += 1;
                    let kv = tokens
                        .get(i)
                        .ok_or("--env without value")?;
                    let (k, v) = kv
                        .split_once('=')
                        .ok_or_else(|| format!("bad --env {kv}"))?;
                    env.push((k.to_string(), v.to_string()));
                }
                _ => rest.push(tokens[i].clone()),
            }
            i += 1;
        }
        if rest.is_empty() {
            return Err(format!("exec line has no image: {line}"));
        }
        lines.push(ExecLine {
            image: rest.remove(0),
            env,
            args: rest,
        });
    }
    Ok((pod_dir, lines))
}

/// The executor: owns a handle to the cluster's container runtime.
pub struct ApptainerExecutor {
    pub runtime: Arc<ApptainerRuntime>,
}

impl ApptainerExecutor {
    pub fn new(runtime: Arc<ApptainerRuntime>) -> ApptainerExecutor {
        ApptainerExecutor { runtime }
    }
}

impl JobExecutor for ApptainerExecutor {
    fn execute(&self, ctx: &JobContext) -> Result<(), String> {
        let (pod_dir, exec_lines) = parse_script_body(&ctx.spec.script)?;
        if exec_lines.is_empty() {
            // Not an HPK pod script (plain batch job): nothing to run.
            return Ok(());
        }
        // The sandbox lives on the first task's node (the pod is one
        // schedulable unit; extra tasks are MPI ranks).
        let first_node = ctx
            .allocation
            .tasks
            .first()
            .map(|t| t.node.clone())
            .ok_or("empty allocation")?;
        let net = self.runtime.create_sandbox(&first_node)?;

        // IP handshake: hpk-kubelet publishes podIP from this file. The
        // write is no state transition, so wake bus subscribers
        // explicitly — the kubelet re-reads on the next event instead
        // of polling the filesystem.
        if let Some(dir) = &pod_dir {
            self.runtime
                .fs
                .write_str(&format!("{dir}/ip"), &net.ip.to_string())
                .map_err(|e| e.to_string())?;
            ctx.progress.notify();
        }

        let ntasks = ctx.spec.ntasks.max(1);
        let mut result: Result<(), String> = Ok(());
        if ntasks == 1 {
            // Plain pod: containers run concurrently in the sandbox.
            result = run_all_containers(self, ctx, &net, &exec_lines);
        } else {
            // MPI-style: the pod's containers are launched once per task
            // slot (srun semantics), each with its rank env.
            let mut handles = Vec::new();
            for task in &ctx.allocation.tasks {
                for line in &exec_lines {
                    let rt = self.runtime.clone();
                    let net = net.clone();
                    let mut line = line.clone();
                    line.env.push((
                        "SLURM_PROCID".to_string(),
                        task.task_id.to_string(),
                    ));
                    line.env
                        .push(("SLURM_NTASKS".to_string(), ntasks.to_string()));
                    line.env.push((
                        "SLURM_JOB_ID".to_string(),
                        ctx.job_id.to_string(),
                    ));
                    for (k, v) in &ctx.spec.env {
                        line.env.push((k.clone(), v.clone()));
                    }
                    let cancel = ctx.cancel.clone();
                    handles.push(std::thread::spawn(move || {
                        rt.run_container(
                            &net, &line.image, &line.args, &line.env, true, cancel,
                        )
                    }));
                }
            }
            for h in handles {
                match h.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => result = Err(e),
                    Err(_) => result = Err("container thread panicked".to_string()),
                }
            }
        }

        self.runtime.destroy_sandbox(&net);
        result
    }
}

fn run_all_containers(
    exec: &ApptainerExecutor,
    ctx: &JobContext,
    net: &crate::apptainer::NetContext,
    lines: &[ExecLine],
) -> Result<(), String> {
    let mut handles = Vec::new();
    for line in lines {
        let rt = exec.runtime.clone();
        let net = net.clone();
        let mut line = line.clone();
        // Downward-API-ish identity from the job.
        if let Some((ns, name)) = ctx.spec.comment.split_once('/') {
            line.env.push(("POD_NAMESPACE".to_string(), ns.to_string()));
            line.env.push(("POD_NAME".to_string(), name.to_string()));
        }
        line.env.push(("POD_IP".to_string(), net.ip.to_string()));
        line.env.push(("NODE_NAME".to_string(), net.node.clone()));
        line.env
            .push(("SLURM_JOB_ID".to_string(), ctx.job_id.to_string()));
        for (k, v) in &ctx.spec.env {
            line.env.push((k.clone(), v.clone()));
        }
        let cancel = ctx.cancel.clone();
        handles.push(std::thread::spawn(move || {
            rt.run_container(&net, &line.image, &line.args, &line.env, true, cancel)
        }));
    }
    let mut result = Ok(());
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => result = Err(e),
            Err(_) => result = Err("container thread panicked".to_string()),
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_generated_script_body() {
        let body = "hpk_pod_dir=/home/user/.hpk/ns/pod\napptainer instance start --cni flannel --fakeroot hpk-pause parent\n\napptainer exec instance://parent --fakeroot --env \"A=hello world\" --env B=2 img:1 cmd --flag x\n";
        let (dir, lines) = parse_script_body(body).unwrap();
        assert_eq!(dir.as_deref(), Some("/home/user/.hpk/ns/pod"));
        assert_eq!(lines.len(), 1);
        let l = &lines[0];
        assert_eq!(l.image, "img:1");
        assert_eq!(l.env[0], ("A".to_string(), "hello world".to_string()));
        assert_eq!(l.env[1], ("B".to_string(), "2".to_string()));
        assert_eq!(l.args, vec!["cmd", "--flag", "x"]);
    }

    #[test]
    fn multiple_exec_lines() {
        let body = "apptainer exec instance://parent --fakeroot a:1\napptainer exec instance://parent --fakeroot b:1 run\n";
        let (_, lines) = parse_script_body(body).unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1].image, "b:1");
    }

    #[test]
    fn malformed_env_rejected() {
        let body = "apptainer exec instance://parent --env NOEQUALS img\n";
        assert!(parse_script_body(body).is_err());
    }

    #[test]
    fn non_hpk_script_is_empty() {
        let (dir, lines) = parse_script_body("echo hello\nexit 0\n").unwrap();
        assert!(dir.is_none());
        assert!(lines.is_empty());
    }

    #[test]
    fn roundtrip_with_translate() {
        let pod = crate::yamlkit::parse_one(
            "kind: Pod\nmetadata:\n  name: p\n  namespace: ns\nspec:\n  containers:\n  - name: c\n    image: worker:1\n    command: [\"run\", \"--n\", \"4\"]\n    env:\n    - name: MODE\n      value: fast\n",
        )
        .unwrap();
        let spec = crate::hpk::translate::pod_to_jobspec(&pod).unwrap();
        let (dir, lines) = parse_script_body(&spec.script).unwrap();
        assert_eq!(dir.as_deref(), Some("/home/user/.hpk/ns/p"));
        assert_eq!(lines[0].image, "worker:1");
        assert_eq!(lines[0].args, vec!["run", "--n", "4"]);
        assert!(lines[0].env.contains(&("MODE".to_string(), "fast".to_string())));
    }
}
