//! hpk-kubelet: the Virtual-Kubelet provider.
//!
//! One kubelet represents the *entire* HPC cluster as a single
//! Kubernetes node. It translates each pod bound to that node into a
//! Slurm script ([`super::translate`]), submits it, and keeps the pod's
//! status in sync with the Slurm job state: "enqueued jobs are marked as
//! 'pending' pods in Kubernetes, 'running' when started, or 'failed' if
//! they produce errors" (SS3). Deleting a pod cancels its job.
//!
//! The sync loop blocks on *one* subscription registered with both
//! event buses — Pod events from the kube store and job transitions
//! from the Slurm bus wake the same condvar (a merged two-source
//! wait). There is no active-bindings poll: a kubelet with a
//! long-running job parked under it costs zero wakeups until either
//! side actually changes.

use super::translate;
use crate::kube::api::ApiServer;
use crate::kube::informer::{SharedInformer, WatchSpec, WorkQueue};
use crate::kube::object;
use crate::kube::store::{Subscription, WakeReason};
use crate::slurm::{JobId, JobState, Slurmctld};
use crate::virtfs::VirtFs;
use crate::yamlkit::Value;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// The name of the single virtual node.
pub const VIRTUAL_NODE: &str = "hpk-kubelet";

/// How long (simulated ms on the cluster clock) the sync loop parks on
/// its merged subscription between events. Both buses wake it
/// immediately; this is only the level-triggered missed-edge backstop,
/// and on a driven clock it fires only when the harness advances
/// virtual time past it.
const RESYNC_BACKSTOP_MS: u64 = 50_000;

struct PodBinding {
    job_id: JobId,
    /// Last phase we pushed, to avoid redundant status writes.
    last_phase: String,
    ip_published: bool,
}

/// The kubelet; cheap to clone (shared state inside).
///
/// Watch-driven on both sides: a private informer feeds Pod keys to
/// the submit path, so translate+sbatch work scales with pod churn,
/// and the sync loop blocks on one subscription woken by Pod events
/// *and* Slurm job events (the per-binding sweep walks the kubelet's
/// own working set, not the cluster object count, and only runs when
/// something actually changed). The same informer caches Service +
/// EndpointSlice so translation can inject service-discovery env.
#[derive(Clone)]
pub struct HpkKubelet {
    api: ApiServer,
    slurm: Slurmctld,
    /// The user's home-directory filesystem (scripts, IP handshakes).
    pub fs: VirtFs,
    bindings: Arc<Mutex<HashMap<String, PodBinding>>>, // pod full name
    shutdown: Arc<AtomicBool>,
    /// Pods translated since boot (metrics).
    translated: Arc<Mutex<u64>>,
    /// scancels issued for deleted pods (metrics + race regression).
    scancels: Arc<AtomicU64>,
    informer: Arc<SharedInformer>,
    queue: WorkQueue,
    subscription: Subscription,
}

impl HpkKubelet {
    /// Register the virtual node and start the sync loop.
    pub fn start(api: ApiServer, slurm: Slurmctld, fs: VirtFs) -> HpkKubelet {
        // Announce the node with the whole cluster's capacity ("a virtual
        // Kubernetes node representing the entire cluster", SS5).
        let (total_cpus, _) = slurm.cluster().cpu_summary();
        let total_mem: u64 = slurm
            .cluster()
            .with_nodes_ref(|ns| ns.iter().map(|n| n.resources.memory_bytes).sum());
        crate::kube::scheduler::register_node(&api, VIRTUAL_NODE, total_cpus, total_mem);

        // Pods drive the loop; Service + EndpointSlice are cached for
        // service-discovery env injection at translation time. Only Pod
        // events wake the loop — service/slice churn is absorbed lazily
        // at the next pod event or backstop sync, so slice writes don't
        // add kubelet wakeups.
        let informer = Arc::new(SharedInformer::for_kinds(
            api.clone(),
            &["Pod", "Service", "EndpointSlice"],
        ));
        let queue = informer.register(vec![WatchSpec::of("Pod")]);
        // One handle, two publishers: Pod events from the store and
        // job transitions (incl. executor progress notifications, e.g.
        // the IP handshake) from the Slurm bus wake the same condvar.
        let subscription = api.subscribe(Some(&["Pod"]));
        slurm.attach(&subscription);
        let kubelet = HpkKubelet {
            api,
            slurm,
            fs,
            bindings: Arc::new(Mutex::new(HashMap::new())),
            shutdown: Arc::new(AtomicBool::new(false)),
            translated: Arc::new(Mutex::new(0)),
            scancels: Arc::new(AtomicU64::new(0)),
            informer,
            queue,
            subscription,
        };
        let k = kubelet.clone();
        std::thread::Builder::new()
            .name("hpk-kubelet".to_string())
            .spawn(move || {
                let clock = k.api.clock().clone();
                while !k.shutdown.load(Ordering::SeqCst) {
                    k.sync_once();
                    // Push-driven end to end: block until either bus
                    // has news (or the shutdown close lands). The
                    // virtual-deadline timeout is only the missed-edge
                    // backstop — an idle kubelet performs zero wakeups
                    // whether or not bindings are in flight.
                    if k.subscription.wait_sim(&clock, RESYNC_BACKSTOP_MS) == WakeReason::Closed {
                        // Either bus closed (kubelet or Slurm shutdown):
                        // one final drain so work that raced the close —
                        // e.g. a pod deletion still needing its scancel —
                        // is processed before the loop exits.
                        k.sync_once();
                        break;
                    }
                }
            })
            .expect("spawn hpk-kubelet");
        kubelet
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the (possibly blocked) sync loop so it exits now.
        self.subscription.close();
    }

    /// Pods translated to Slurm scripts since boot.
    pub fn translated_count(&self) -> u64 {
        *self.translated.lock().unwrap()
    }

    /// scancels issued for deleted pods since boot.
    pub fn scancel_count(&self) -> u64 {
        self.scancels.load(Ordering::SeqCst)
    }

    /// Wakeups delivered to the sync loop's merged subscription — the
    /// observability hook behind the E5.3e zero-idle-wakeup bench.
    pub fn wakeup_count(&self) -> u64 {
        self.subscription.notify_count()
    }

    /// One reconcile pass (public for deterministic tests/benches).
    pub fn sync_once(&self) {
        // 1. Changed pods bound to us -> translate + sbatch.
        self.informer.sync();
        for key in self.queue.drain() {
            if key.kind != "Pod" {
                continue;
            }
            let Some(pod) = self.informer.get(&key) else {
                continue; // deletion: handled via the binding sweep below
            };
            if pod.str_at("spec.nodeName") != Some(VIRTUAL_NODE) {
                continue;
            }
            let full = key.full_name();
            if self.bindings.lock().unwrap().contains_key(&full) {
                continue;
            }
            let phase = object::pod_phase(&pod);
            // Restart adoption: a pod already carrying a job-id
            // annotation was submitted by an earlier kubelet life —
            // re-adopt that binding instead of sbatching a duplicate.
            if let Some(job_id) = object::annotation(&pod, super::annotations::JOB_ID)
                .and_then(|s| s.parse::<JobId>().ok())
            {
                if phase == "Pending" || phase == "Running" {
                    self.bindings.lock().unwrap().entry(full).or_insert(PodBinding {
                        job_id,
                        last_phase: String::new(),
                        ip_published: false,
                    });
                }
                continue;
            }
            if phase != "Pending" {
                continue; // already processed in an earlier life
            }
            self.submit_pod(&pod, full);
        }

        // 2. Sync Slurm job state -> pod status; scancel deleted pods.
        let snapshot: Vec<(String, JobId)> = {
            let bindings = self.bindings.lock().unwrap();
            bindings
                .iter()
                .map(|(k, b)| (k.clone(), b.job_id))
                .collect()
        };
        for (full, job_id) in snapshot {
            let (ns, name) = full.split_once('/').unwrap();
            let pod = self.api.get("Pod", ns, name).ok();
            let job = self.slurm.job_info(job_id);
            match (pod, job) {
                (None, Some(info)) => {
                    // Pod deleted by the user -> cancel the Slurm job.
                    // Claim the binding *first*: exactly one pass wins
                    // the removal, so the scancel below runs exactly
                    // once even when concurrent sync passes race or the
                    // job is mid-transition (Pending->Running) — the
                    // controller resolves whatever state the job is in
                    // by the time the cancel lands.
                    if self.bindings.lock().unwrap().remove(&full).is_none() {
                        continue; // another pass already claimed it
                    }
                    if !info.state.is_terminal() && self.slurm.cancel(job_id) {
                        self.scancels.fetch_add(1, Ordering::SeqCst);
                    }
                    self.fs.remove_tree(&translate::pod_dir(ns, name));
                }
                (Some(_pod), Some(info)) => {
                    self.sync_pod_status(&full, ns, name, &info.state);
                    if info.state.is_terminal() {
                        self.bindings.lock().unwrap().remove(&full);
                    }
                }
                (_, None) => {
                    self.bindings.lock().unwrap().remove(&full);
                }
            }
        }
    }

    fn submit_pod(&self, pod: &Value, full: String) {
        let ns = object::namespace(pod).to_string();
        let name = object::name(pod).to_string();
        // Resolve ConfigMap/Secret references and inject the
        // service-discovery env (aggregated from the cached
        // EndpointSlice shards) before translation, so the generated
        // script carries concrete values.
        let pod = &resolve_env_refs(&self.api, pod);
        let services = crate::kube::kubelet::service_env(&self.informer, &ns);
        let pod = &inject_service_env(pod, &services);
        match translate::pod_to_jobspec(pod) {
            Ok(spec) => {
                // Persist the script in the user's home dir (HPK keeps all
                // of its state there) before submitting.
                let script = crate::slurm::script::render_script(&spec);
                let _ = self.fs.write_str(
                    &format!("{}/job.sbatch", translate::pod_dir(&ns, &name)),
                    &script,
                );
                match self.slurm.submit(spec) {
                    Ok(job_id) => {
                        *self.translated.lock().unwrap() += 1;
                        self.bindings.lock().unwrap().insert(
                            full,
                            PodBinding {
                                job_id,
                                last_phase: String::new(),
                                ip_published: false,
                            },
                        );
                        // Record the job id on the pod for transparency.
                        let mut patch = Value::map();
                        patch
                            .entry_map("metadata")
                            .entry_map("annotations")
                            .set(
                                super::annotations::JOB_ID,
                                Value::from(job_id.to_string()),
                            );
                        let _ = self.api.patch("Pod", &ns, &name, &patch);
                        self.api.record_event(
                            &ns,
                            &format!("Pod/{name}"),
                            "SlurmSubmitted",
                            &format!("job {job_id}"),
                        );
                    }
                    Err(e) => {
                        let mut st = Value::map();
                        st.set("phase", Value::from("Failed"));
                        st.set("reason", Value::from(format!("sbatch: {e}")));
                        let _ = self.api.update_status("Pod", &ns, &name, st);
                    }
                }
            }
            Err(e) => {
                let mut st = Value::map();
                st.set("phase", Value::from("Failed"));
                st.set("reason", Value::from(format!("translate: {e}")));
                let _ = self.api.update_status("Pod", &ns, &name, st);
            }
        }
    }

    fn sync_pod_status(&self, full: &str, ns: &str, name: &str, state: &JobState) {
        let (phase, reason): (&str, Option<String>) = match state {
            JobState::Pending(r) => ("Pending", Some(r.clone())),
            JobState::Running => ("Running", None),
            JobState::Completed => ("Succeeded", None),
            JobState::Failed(e) => ("Failed", Some(e.clone())),
            JobState::Cancelled => ("Failed", Some("Cancelled".to_string())),
            JobState::Timeout => ("Failed", Some("DeadlineExceeded".to_string())),
        };
        // IP handshake file (written by the executor when the sandbox is
        // up). Publish once.
        let ip = self
            .fs
            .read_str(&format!("{}/ip", translate::pod_dir(ns, name)))
            .ok();
        let mut bindings = self.bindings.lock().unwrap();
        let Some(binding) = bindings.get_mut(full) else {
            return;
        };
        let need_ip = !binding.ip_published && ip.is_some();
        if binding.last_phase == phase && !need_ip {
            return;
        }
        binding.last_phase = phase.to_string();
        if need_ip {
            binding.ip_published = true;
        }
        drop(bindings);

        let mut status = Value::map();
        status.set("phase", Value::from(phase));
        if phase == "Succeeded" || phase == "Failed" {
            // Stamp the tombstone time the GC's cap/TTL sweep keys on
            // (same clock the GC reads: the API server's).
            status.set(
                "terminatedAt",
                Value::Int(self.api.clock().now_ms() as i64),
            );
        }
        if let Some(r) = reason {
            status.set("reason", Value::from(r));
        }
        if let Some(ip) = ip {
            status.set("podIP", Value::from(ip));
        }
        let _ = self.api.update_status("Pod", ns, name, status);
    }
}

/// Rewrite `env[].valueFrom.{configMapKeyRef,secretKeyRef}` into plain
/// values by reading the referenced objects — the kubelet's
/// responsibility in real Kubernetes, done at translation time in HPK
/// so the sbatch script is self-contained.
pub fn resolve_env_refs(api: &ApiServer, pod: &Value) -> Value {
    let mut pod = pod.clone();
    let ns = object::namespace(&pod).to_string();
    let Some(Value::Seq(containers)) =
        pod.entry_map("spec").get_mut("containers").map(|c| {
            // Take ownership via std::mem::replace pattern below.
            c
        })
    else {
        return pod;
    };
    for c in containers.iter_mut() {
        let Some(Value::Seq(env)) = c.get_mut("env") else {
            continue;
        };
        for item in env.iter_mut() {
            if item.get("value").is_some() {
                continue;
            }
            let resolved = ["configMapKeyRef", "secretKeyRef"]
                .iter()
                .find_map(|ref_kind| {
                    let r = item.path(&format!("valueFrom.{ref_kind}"))?;
                    let obj_name = r.str_at("name")?;
                    let key = r.str_at("key")?;
                    let kind = if *ref_kind == "configMapKeyRef" {
                        "ConfigMap"
                    } else {
                        "Secret"
                    };
                    let obj = api.get(kind, &ns, obj_name).ok()?;
                    obj.path("data")?.get(key)?.coerce_string()
                });
            if let Some(v) = resolved {
                item.remove("valueFrom");
                item.set("value", Value::from(v));
            }
        }
    }
    pod
}

/// Append service-discovery env entries (`<SVC>_SERVICE_HOST`/`_PORT`,
/// see [`crate::kube::kubelet::service_env`]) to every container that
/// doesn't already set them — the HPK counterpart of the kubelet
/// injecting service env at container start, done at translation time
/// so the sbatch script is self-contained.
pub fn inject_service_env(pod: &Value, services: &[(String, String)]) -> Value {
    if services.is_empty() {
        return pod.clone();
    }
    let mut pod = pod.clone();
    let Some(Value::Seq(containers)) = pod.entry_map("spec").get_mut("containers") else {
        return pod;
    };
    for c in containers.iter_mut() {
        let existing: std::collections::BTreeSet<String> = c
            .path("env")
            .and_then(|e| e.as_seq())
            .map(|items| {
                items
                    .iter()
                    .filter_map(|i| i.str_at("name").map(|s| s.to_string()))
                    .collect()
            })
            .unwrap_or_default();
        if !matches!(c.get("env"), Some(Value::Seq(_))) {
            c.set("env", Value::Seq(Vec::new()));
        }
        let Some(Value::Seq(env)) = c.get_mut("env") else {
            continue;
        };
        for (k, v) in services {
            if existing.contains(k) {
                continue;
            }
            let mut item = Value::map();
            item.set("name", Value::from(k.as_str()));
            item.set("value", Value::from(v.as_str()));
            env.push(item);
        }
    }
    pod
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apptainer::{ApptainerRuntime, ImageSpec};
    use crate::hpcsim::{Cluster, ClusterSpec};
    use crate::hpk::executor::ApptainerExecutor;
    use crate::hpk::PassThroughScheduler;
    use crate::kube::controllers::testutil::reconcile_once;
    use crate::slurm::SlurmConfig;
    use crate::yamlkit::parse_one;

    struct World {
        api: ApiServer,
        kubelet: HpkKubelet,
        slurm: Slurmctld,
        runtime: Arc<ApptainerRuntime>,
    }

    fn world() -> World {
        let cluster = Cluster::new(ClusterSpec::uniform(2, 8, 32));
        let fs = VirtFs::new();
        let runtime = Arc::new(ApptainerRuntime::new(
            fs.clone(),
            cluster.clock.clone(),
            true,
        ));
        runtime
            .registry
            .register(ImageSpec::new("quick:1", "quick").with_size(1 << 20));
        runtime.table.register("quick", |_| Ok(0));
        runtime
            .registry
            .register(ImageSpec::new("server:1", "server").with_size(1 << 20));
        runtime.table.register("server", |ctx| {
            ctx.cancel.wait();
            Err("terminated".to_string())
        });
        let slurm = Slurmctld::start(
            cluster,
            Arc::new(ApptainerExecutor::new(runtime.clone())),
            SlurmConfig::default(),
        );
        let api = ApiServer::new();
        let kubelet = HpkKubelet::start(api.clone(), slurm.clone(), fs);
        World { api, kubelet, slurm, runtime }
    }

    fn wait_phase(api: &ApiServer, ns: &str, name: &str, phase: &str, ms: u64) -> bool {
        let sub = api.subscribe(Some(&["Pod"]));
        crate::util::sub::wait_for(&sub, ms, 50, || {
            api.get("Pod", ns, name)
                .map(|p| object::pod_phase(&p) == phase)
                .unwrap_or(false)
        })
    }

    fn quick_pod(name: &str) -> Value {
        parse_one(&format!(
            "kind: Pod\nmetadata:\n  name: {name}\nspec:\n  containers:\n  - name: main\n    image: quick:1\n"
        ))
        .unwrap()
    }

    #[test]
    fn virtual_node_registered() {
        let w = world();
        let node = w.api.get("Node", "default", VIRTUAL_NODE).unwrap();
        assert_eq!(node.i64_at("status.capacity.cpu"), Some(16));
        w.kubelet.shutdown();
        w.slurm.shutdown();
    }

    #[test]
    fn pod_runs_through_slurm_to_success() {
        let w = world();
        w.api.create(quick_pod("p1")).unwrap();
        reconcile_once(&w.api, &PassThroughScheduler);
        assert!(wait_phase(&w.api, "default", "p1", "Succeeded", 5000));
        // The pod was visible in Slurm accounting with the ns/name comment.
        let acct = w.slurm.sacct();
        assert!(acct.iter().any(|r| r.comment == "default/p1"));
        // The generated script landed in the home dir.
        let script = w
            .kubelet
            .fs
            .read_str("/home/user/.hpk/default/p1/job.sbatch")
            .unwrap();
        assert!(script.contains("apptainer exec"));
        assert_eq!(w.kubelet.translated_count(), 1);
        w.kubelet.shutdown();
        w.slurm.shutdown();
    }

    #[test]
    fn server_pod_gets_ip_then_cancelled_on_delete() {
        let w = world();
        w.api
            .create(
                parse_one(
                    "kind: Pod\nmetadata:\n  name: srv\nspec:\n  containers:\n  - name: main\n    image: server:1\n",
                )
                .unwrap(),
            )
            .unwrap();
        reconcile_once(&w.api, &PassThroughScheduler);
        assert!(wait_phase(&w.api, "default", "srv", "Running", 5000));
        // IP handshake published (pod-status writes wake the waiter).
        let sub = w.api.subscribe(Some(&["Pod"]));
        assert!(
            crate::util::sub::wait_for(&sub, 5_000, 50, || {
                let p = w.api.get("Pod", "default", "srv").unwrap();
                p.str_at("status.podIP").map(|s| s.starts_with("10.244.")) == Some(true)
            }),
            "no podIP published"
        );
        // Delete -> scancel -> sandbox freed. The sandbox teardown is
        // not a bus event, so this rides the backstop.
        w.api.delete("Pod", "default", "srv").unwrap();
        let drain = w.slurm.subscribe();
        assert!(
            crate::util::sub::wait_for(&drain, 15_000, 50, || {
                w.runtime.cni.live_count() == 0
            }),
            "sandbox not freed"
        );
        w.kubelet.shutdown();
        w.slurm.shutdown();
    }

    #[test]
    fn bad_image_fails_pod() {
        let w = world();
        w.api
            .create(
                parse_one(
                    "kind: Pod\nmetadata:\n  name: ghost\nspec:\n  containers:\n  - name: main\n    image: missing:9\n",
                )
                .unwrap(),
            )
            .unwrap();
        reconcile_once(&w.api, &PassThroughScheduler);
        assert!(wait_phase(&w.api, "default", "ghost", "Failed", 5000));
        w.kubelet.shutdown();
        w.slurm.shutdown();
    }

    #[test]
    fn configmap_env_resolved_into_script() {
        let w = world();
        w.api
            .create(
                parse_one(
                    "kind: ConfigMap\nmetadata:\n  name: app-config\ndata:\n  MODE: turbo\n",
                )
                .unwrap(),
            )
            .unwrap();
        w.api
            .create(
                parse_one(
                    "kind: Pod\nmetadata:\n  name: cfg\nspec:\n  containers:\n  - name: main\n    image: quick:1\n    env:\n    - name: MODE\n      valueFrom:\n        configMapKeyRef:\n          name: app-config\n          key: MODE\n",
                )
                .unwrap(),
            )
            .unwrap();
        reconcile_once(&w.api, &PassThroughScheduler);
        assert!(wait_phase(&w.api, "default", "cfg", "Succeeded", 5000));
        let script = w
            .kubelet
            .fs
            .read_str("/home/user/.hpk/default/cfg/job.sbatch")
            .unwrap();
        assert!(script.contains("--env MODE=turbo"), "{script}");
        w.kubelet.shutdown();
        w.slurm.shutdown();
    }

    #[test]
    fn service_env_injected_into_script() {
        use crate::kube::controllers::EndpointsController;
        let w = world();
        w.api
            .create(
                parse_one(
                    "kind: Service\nmetadata:\n  name: db\nspec:\n  clusterIP: None\n  selector:\n    app: db\n  ports:\n  - port: 5432\n",
                )
                .unwrap(),
            )
            .unwrap();
        w.api
            .create(
                parse_one(
                    "kind: Pod\nmetadata:\n  name: db-backing\n  labels:\n    app: db\nspec: {}\nstatus:\n  phase: Running\n  podIP: 10.244.9.9\n",
                )
                .unwrap(),
            )
            .unwrap();
        reconcile_once(&w.api, &EndpointsController);
        assert!(!w.api.list("EndpointSlice").is_empty());

        w.api.create(quick_pod("uses-db")).unwrap();
        reconcile_once(&w.api, &PassThroughScheduler);
        assert!(wait_phase(&w.api, "default", "uses-db", "Succeeded", 5000));
        let script = w
            .kubelet
            .fs
            .read_str("/home/user/.hpk/default/uses-db/job.sbatch")
            .unwrap();
        assert!(script.contains("--env DB_SERVICE_HOST=10.244.9.9"), "{script}");
        assert!(script.contains("--env DB_SERVICE_PORT=5432"), "{script}");
    }

    #[test]
    fn deleted_pod_scancels_exactly_once_under_racing_syncs() {
        let w = world();
        w.api
            .create(
                parse_one(
                    "kind: Pod\nmetadata:\n  name: racy\nspec:\n  containers:\n  - name: main\n    image: server:1\n",
                )
                .unwrap(),
            )
            .unwrap();
        reconcile_once(&w.api, &PassThroughScheduler);
        assert!(wait_phase(&w.api, "default", "racy", "Running", 5000));
        w.api.delete("Pod", "default", "racy").unwrap();
        // Race several explicit sync passes against the push-woken
        // background loop: the binding claim must let exactly one of
        // them issue the scancel.
        let mut handles = Vec::new();
        for _ in 0..8 {
            let k = w.kubelet.clone();
            handles.push(std::thread::spawn(move || k.sync_once()));
        }
        for h in handles {
            h.join().unwrap();
        }
        let drain = w.slurm.subscribe();
        assert!(
            crate::util::sub::wait_for(&drain, 10_000, 50, || w.slurm.squeue().is_empty()),
            "job not cancelled"
        );
        assert_eq!(w.kubelet.scancel_count(), 1);
        w.kubelet.shutdown();
        w.slurm.shutdown();
    }

    #[test]
    fn pod_deleted_while_job_pending_is_cancelled_exactly_once() {
        // A scheduler that effectively never passes: the submitted job
        // stays Pending, so the deletion lands strictly mid-transition
        // (between sbatch and the job ever starting).
        let cluster = Cluster::new(ClusterSpec::uniform(1, 4, 16));
        let fs = VirtFs::new();
        let runtime = Arc::new(ApptainerRuntime::new(
            fs.clone(),
            cluster.clock.clone(),
            true,
        ));
        runtime
            .registry
            .register(ImageSpec::new("quick:1", "quick").with_size(1 << 20));
        runtime.table.register("quick", |_| Ok(0));
        let slurm = Slurmctld::start(
            cluster,
            Arc::new(ApptainerExecutor::new(runtime)),
            SlurmConfig { sched_interval_ms: 3_600_000, ..SlurmConfig::default() },
        );
        // Wait out the startup pass (over an empty queue): only then is
        // the scheduler guaranteed asleep, so the job submitted below
        // stays Pending instead of racing into execution. No pass
        // event exists, so this rides the backstop.
        let events = slurm.subscribe();
        assert!(
            crate::util::sub::wait_for(&events, 5_000, 20, || slurm.sched_passes() > 0),
            "startup pass never ran"
        );
        let api = ApiServer::new();
        let kubelet = HpkKubelet::start(api.clone(), slurm.clone(), fs);
        api.create(quick_pod("doomed")).unwrap();
        reconcile_once(&api, &PassThroughScheduler);
        assert!(
            crate::util::sub::wait_for(&events, 5_000, 50, || !slurm.squeue().is_empty()),
            "job never submitted"
        );
        let job_id = slurm.squeue()[0].job_id;
        assert!(matches!(
            slurm.job_info(job_id).unwrap().state,
            JobState::Pending(_)
        ));
        api.delete("Pod", "default", "doomed").unwrap();
        assert!(
            crate::util::sub::wait_for(&events, 5_000, 50, || {
                slurm.job_info(job_id).unwrap().state == JobState::Cancelled
            }),
            "pending job not cancelled"
        );
        // Extra racing passes must not cancel again.
        for _ in 0..4 {
            kubelet.sync_once();
        }
        assert_eq!(kubelet.scancel_count(), 1);
        assert!(slurm
            .sacct()
            .iter()
            .any(|r| r.job_id == job_id && r.state == JobState::Cancelled));
        kubelet.shutdown();
        slurm.shutdown();
    }

    #[test]
    fn job_id_annotation_recorded() {
        let w = world();
        w.api.create(quick_pod("p2")).unwrap();
        reconcile_once(&w.api, &PassThroughScheduler);
        assert!(wait_phase(&w.api, "default", "p2", "Succeeded", 5000));
        let pod = w.api.get("Pod", "default", "p2").unwrap();
        assert!(object::annotation(&pod, super::super::annotations::JOB_ID).is_some());
        w.kubelet.shutdown();
        w.slurm.shutdown();
    }
}
