//! High-Performance Kubernetes — the paper's contribution.
//!
//! HPK runs a private, unprivileged Kubernetes control plane whose pods
//! execute as Slurm jobs via Apptainer (SS3):
//!
//! - [`kubelet`] — **hpk-kubelet**, a Virtual-Kubelet provider that
//!   represents the whole HPC cluster as a single Kubernetes node and
//!   translates pod lifecycle to Slurm scripts of Apptainer commands,
//!   syncing Slurm job states back to pod phases.
//! - [`translate`] — the pod -> sbatch-script translation, including the
//!   `slurm-job.hpk.io/*` annotation pass-through (paper Listing 2).
//! - [`executor`] — the Slurm-side interpreter of those scripts: starts
//!   the pod sandbox (parent container, CNI IP) and the per-container
//!   Apptainer invocations; fans MPI-style jobs out over task slots.
//! - [`scheduler`] — the pass-through scheduler: "makes no scheduling
//!   decisions, but always selects hpk-kubelet to run workloads".
//! - [`admission`] — the service admission controller that disables
//!   ClusterIP services (everything becomes headless) and rejects
//!   NodePort, removing the need for a root-level kube-proxy.
//! - [`controlplane`] — the control-plane-container equivalent:
//!   bootstraps all components in order and emits a kubeconfig.
//!
//! # Event flow
//!
//! HPK is push-driven end to end; nothing in the pod path polls:
//!
//! 1. A pod lands in the store; the pass-through scheduler's
//!    subscription wakes, it binds the pod to [`VIRTUAL_NODE`]. Pods
//!    carrying a [`annotations::POD_GROUP`] annotation are held until
//!    every declared member exists, then bound together — the K8s half
//!    of gang placement (the Slurm half is all-or-nothing group
//!    reservation; see *Gang scheduling & preemption* in
//!    [`crate::slurm`]).
//! 2. The bind event wakes hpk-kubelet's merged subscription (one
//!    handle registered with the kube store for `Pod` *and* with the
//!    Slurm job-event bus for every job). It translates, sbatches, and
//!    records the binding.
//! 3. Slurm state changes (`Pending -> Running -> terminal`) are
//!    published on [`crate::slurm::Slurmctld`]'s event bus and wake the
//!    same handle; the kubelet mirrors them into pod status. Executor
//!    milestones that are not transitions (the pod-IP handshake file)
//!    wake it through [`crate::slurm::ProgressNotifier`].
//! 4. A pod deletion event arrives the same way; the kubelet claims
//!    the binding and `scancel`s exactly once.
//!
//! An idle deployment — even one with long jobs parked under the
//! kubelet — costs zero wakeups (bench E5.3e); the old 2 ms
//! active-bindings poll is gone.

pub mod admission;
pub mod controlplane;
pub mod executor;
pub mod kubelet;
pub mod scheduler;
pub mod translate;

pub use controlplane::{ControlPlane, HpkConfig};
pub use kubelet::{HpkKubelet, VIRTUAL_NODE};
pub use scheduler::PassThroughScheduler;

/// Annotation keys HPK recognises on pods (SS4.2).
pub mod annotations {
    /// Extra generic Slurm flags, forwarded verbatim.
    pub const SLURM_FLAGS: &str = "slurm-job.hpk.io/flags";
    /// MPI-launcher flags (recorded in the script; informational here).
    pub const MPI_FLAGS: &str = "slurm-job.hpk.io/mpi-flags";
    /// Set by hpk-kubelet: the Slurm job id backing this pod.
    pub const JOB_ID: &str = "slurm-job.hpk.io/id";
    /// PodGroup (gang) name: pods in one namespace sharing this value
    /// are bound and placed all-or-nothing (Slurm-side gang placement;
    /// see *Gang scheduling* in [`crate::slurm`]).
    pub const POD_GROUP: &str = "slurm-job.hpk.io/pod-group";
    /// Declared member count of the PodGroup; the pass-through
    /// scheduler holds binding until this many members exist and the
    /// Slurm scheduler holds placement until all are submitted.
    pub const POD_GROUP_SIZE: &str = "slurm-job.hpk.io/pod-group-size";
    /// "true" marks the backing Slurm job preemptible by
    /// higher-priority gangs (scancel-and-requeue).
    pub const PREEMPTIBLE: &str = "slurm-job.hpk.io/preemptible";
}
