//! HPK's service admission controller.
//!
//! "To avoid the network proxy, HPK completely disables ClusterIP
//! services, via a Kubernetes admission controller" (SS3). Every Service
//! is mutated to be headless (`clusterIP: None`); NodePort services —
//! which the paper's compatibility requirement carves out as the one
//! unsupported construct — are rejected outright.

use crate::kube::api::{AdmissionCheck, AdmissionOp};
use crate::yamlkit::Value;
use std::sync::Arc;

/// Build the admission check to register with the API server.
pub fn service_admission() -> AdmissionCheck {
    Arc::new(|op: AdmissionOp, obj: &mut Value| {
        if op == AdmissionOp::Delete || obj.str_at("kind") != Some("Service") {
            return Ok(());
        }
        match obj.str_at("spec.type") {
            Some("NodePort") | Some("LoadBalancer") => {
                return Err(format!(
                    "{} services are not supported on HPK (no root-level \
                     network proxy); use a headless ClusterIP service",
                    obj.str_at("spec.type").unwrap()
                ));
            }
            _ => {}
        }
        // Force headless: discovery through CoreDNS -> pod IPs.
        obj.entry_map("spec").set("clusterIP", Value::from("None"));
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kube::api::ApiServer;
    use crate::yamlkit::parse_one;

    fn api_with_admission() -> ApiServer {
        let api = ApiServer::new();
        api.register_admission(service_admission());
        api
    }

    #[test]
    fn services_become_headless() {
        let api = api_with_admission();
        let svc = parse_one(
            "kind: Service\nmetadata:\n  name: web\nspec:\n  clusterIP: 10.96.0.1\n  selector:\n    app: web\n",
        )
        .unwrap();
        let created = api.create(svc).unwrap();
        assert_eq!(created.str_at("spec.clusterIP"), Some("None"));
    }

    #[test]
    fn nodeport_rejected() {
        let api = api_with_admission();
        let svc = parse_one(
            "kind: Service\nmetadata:\n  name: np\nspec:\n  type: NodePort\n",
        )
        .unwrap();
        let err = api.create(svc).unwrap_err();
        assert!(err.to_string().contains("NodePort"));
    }

    #[test]
    fn loadbalancer_rejected() {
        let api = api_with_admission();
        let svc = parse_one(
            "kind: Service\nmetadata:\n  name: lb\nspec:\n  type: LoadBalancer\n",
        )
        .unwrap();
        assert!(api.create(svc).is_err());
    }

    #[test]
    fn non_services_untouched() {
        let api = api_with_admission();
        let pod = parse_one("kind: Pod\nmetadata:\n  name: p\nspec: {}\n").unwrap();
        let created = api.create(pod).unwrap();
        assert!(created.str_at("spec.clusterIP").is_none());
    }

    #[test]
    fn update_also_mutated() {
        let api = api_with_admission();
        let svc = parse_one("kind: Service\nmetadata:\n  name: s\nspec: {}\n").unwrap();
        let mut created = api.create(svc).unwrap();
        created
            .entry_map("spec")
            .set("clusterIP", Value::from("10.0.0.1"));
        let updated = api.update(created).unwrap();
        assert_eq!(updated.str_at("spec.clusterIP"), Some("None"));
    }
}
