//! Control-plane bootstrap — the "control plane container" of Figure 3.
//!
//! "At runtime, it generates all necessary internal keys and
//! certificates, bootstraps the Kubernetes control plane by initializing
//! the executables in order, and produces the configuration file
//! containing the endpoint and credentials needed to connect" (SS3).
//! Here that means: assemble store/API server, register HPK's admission
//! controller, start the controller manager, pass-through scheduler and
//! CoreDNS, connect hpk-kubelet to Slurm, and emit a kubeconfig
//! [`Value`] to the user's home directory.

use super::admission::service_admission;
use super::executor::ApptainerExecutor;
use super::kubelet::HpkKubelet;
use super::scheduler::PassThroughScheduler;
use crate::apptainer::ApptainerRuntime;
use crate::hpcsim::{Cluster, ClusterSpec};
use crate::kube::api::ApiServer;
use crate::kube::controllers::{
    ControllerManager, DeploymentController, EndpointsController, GcController,
    HpaController, JobController, ReplicaSetController,
};
use crate::kube::coredns::CoreDns;
use crate::slurm::{Slurmctld, SlurmConfig};
use crate::traffic::{PodMetrics, ServiceProxy};
use crate::util::Rng;
use crate::virtfs::VirtFs;
use crate::yamlkit::Value;
use std::sync::Arc;

/// Deployment-time knobs.
#[derive(Debug, Clone)]
pub struct HpkConfig {
    pub cluster: ClusterSpec,
    pub slurm: SlurmConfig,
    /// Host-level fakeroot opt-in (the one change HPK asks admins for).
    pub fakeroot_allowed: bool,
}

impl Default for HpkConfig {
    fn default() -> HpkConfig {
        HpkConfig {
            cluster: ClusterSpec::uniform(4, 16, 64),
            slurm: SlurmConfig::default(),
            fakeroot_allowed: true,
        }
    }
}

/// A running HPK deployment: every component, plus user-facing handles.
pub struct ControlPlane {
    pub api: ApiServer,
    pub dns: CoreDns,
    pub slurm: Slurmctld,
    pub kubelet: HpkKubelet,
    pub runtime: Arc<ApptainerRuntime>,
    pub fs: VirtFs,
    pub cluster: Cluster,
    pub kubeconfig: Value,
    /// The deployment's shared request-metrics source: serving
    /// containers and load generators record into it, the HPA scales
    /// from it. Also published in the runtime's service hub.
    pub metrics: Arc<PodMetrics>,
    /// Client-side service dataplane over the EndpointSlice cache.
    pub proxy: ServiceProxy,
    controller_manager: Option<ControllerManager>,
}

impl ControlPlane {
    /// Boot HPK on a fresh simulated cluster.
    pub fn deploy(config: HpkConfig) -> ControlPlane {
        let cluster = Cluster::new(config.cluster.clone());
        let fs = VirtFs::new();
        fs.add_mount("/home", "lustre-home", 0, false);
        fs.add_mount("/mnt/nvme", "nvme-local", 0, false);

        let runtime = Arc::new(ApptainerRuntime::new(
            fs.clone(),
            cluster.clock.clone(),
            config.fakeroot_allowed,
        ));

        // "Generates all necessary internal keys and certificates":
        // deterministic pseudo-credentials, kept in the kubeconfig.
        let mut rng = Rng::new(0x48504b); // "HPK"
        let token = format!("hpk-token-{:016x}", rng.next_u64());
        let ca_cert = format!("hpk-ca-{:016x}", rng.next_u64());

        // Order matters, mirroring the control-plane container: store +
        // API server first, stamping timestamps from the cluster clock
        // so every component (and the GC's TTL sweeps) shares one time
        // source, ...
        let api = ApiServer::with_clock(cluster.clock.clone());
        api.register_admission(service_admission());

        // ... then Slurm connectivity for the kubelet, ...
        let slurm = Slurmctld::start(
            cluster.clone(),
            Arc::new(ApptainerExecutor::new(runtime.clone())),
            config.slurm.clone(),
        );

        // Request metrics predate the controller manager: the HPA
        // reconciler parks on this hub, and serving containers find it
        // through the runtime's service hub.
        let metrics = Arc::new(PodMetrics::new(cluster.clock.clone()));
        runtime.hub.insert(metrics.clone());

        // ... then the controller manager (+ HPK's scheduler + the
        // autoscaler): one push-woken thread per reconciler, no poll
        // tick — the control plane costs nothing while the cluster is
        // quiet.
        let controller_manager = ControllerManager::start(
            api.clone(),
            vec![
                Box::new(DeploymentController),
                Box::new(ReplicaSetController),
                Box::new(JobController),
                Box::new(EndpointsController),
                Box::new(GcController),
                Box::new(PassThroughScheduler),
                Box::new(HpaController::new(metrics.clone(), cluster.clock.clone())),
            ],
        );

        // ... then CoreDNS, the service dataplane, and finally the
        // kubelet announcing its node.
        let dns = CoreDns::new(api.clone());
        let proxy = ServiceProxy::new(api.clone());
        let kubelet = HpkKubelet::start(api.clone(), slurm.clone(), fs.clone());

        // Produce the kubeconfig in the home directory.
        let mut kubeconfig = Value::map();
        kubeconfig.set("apiVersion", Value::from("v1"));
        kubeconfig.set("kind", Value::from("Config"));
        kubeconfig.set("current-context", Value::from("hpk"));
        let mut cluster_entry = Value::map();
        cluster_entry.set("server", Value::from("https://hpk-apiserver:6443"));
        cluster_entry.set("certificate-authority-data", Value::from(ca_cert));
        kubeconfig.set("cluster", cluster_entry);
        let mut user = Value::map();
        user.set("token", Value::from(token));
        kubeconfig.set("user", user);
        let _ = fs.write_str(
            "/home/user/.hpk/kubeconfig",
            &crate::yamlkit::to_yaml_string(&kubeconfig),
        );

        ControlPlane {
            api,
            dns,
            slurm,
            kubelet,
            runtime,
            fs,
            cluster,
            kubeconfig,
            metrics,
            proxy,
            controller_manager: Some(controller_manager),
        }
    }

    /// `kubectl apply -f` equivalent.
    pub fn kubectl_apply(&self, manifest: &str) -> Result<Vec<Value>, crate::kube::ApiError> {
        self.api.apply_manifest(manifest)
    }

    /// Ready addresses of a service, aggregated from its EndpointSlice
    /// shards (CoreDNS's informer cache — no per-call API fetch, no
    /// whole-service Endpoints object anywhere).
    pub fn service_endpoints(&self, namespace: &str, service: &str) -> Vec<String> {
        self.dns.service_endpoints(namespace, service)
    }

    /// Wait until a pod reaches `phase` (real-ms timeout). Returns the
    /// final pod object on success. Push-driven: parks on a Pod
    /// subscription, so the check re-runs only when a pod actually
    /// changes.
    pub fn wait_for_phase(
        &self,
        namespace: &str,
        name: &str,
        phase: &str,
        timeout_ms: u64,
    ) -> Option<Value> {
        let sub = self.api.subscribe(Some(&["Pod"]));
        let mut found = None;
        crate::util::sub::wait_for(&sub, timeout_ms, timeout_ms, || {
            match self.api.get("Pod", namespace, name) {
                Ok(p) if crate::kube::object::pod_phase(&p) == phase => {
                    found = Some(p);
                    true
                }
                _ => false,
            }
        });
        found
    }

    /// Block until `cond(api)` holds. Rides both event buses (every
    /// store kind plus Slurm job transitions wake the re-check), with a
    /// coarse backstop for conditions over non-bus state (DNS caches,
    /// fabric bindings).
    pub fn wait_until(
        &self,
        timeout_ms: u64,
        mut cond: impl FnMut(&ApiServer) -> bool,
    ) -> bool {
        let sub = self.api.subscribe(None);
        self.slurm.attach(&sub);
        crate::util::sub::wait_for(&sub, timeout_ms, 50, || cond(&self.api))
    }

    /// Orderly teardown of all loops. Closes the cluster clock last,
    /// so any thread still parked on a virtual deadline (a driven
    /// clock that will never advance again) unwedges immediately.
    pub fn shutdown(mut self) {
        self.kubelet.shutdown();
        if let Some(cm) = self.controller_manager.take() {
            cm.shutdown();
        }
        self.slurm.shutdown();
        self.cluster.clock.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apptainer::ImageSpec;
    use crate::kube::object;

    fn deploy_small() -> ControlPlane {
        let cp = ControlPlane::deploy(HpkConfig {
            cluster: ClusterSpec::uniform(2, 8, 32),
            ..HpkConfig::default()
        });
        cp.runtime
            .registry
            .register(ImageSpec::new("quick:1", "quick").with_size(1 << 20));
        cp.runtime.table.register("quick", |_| Ok(0));
        cp.runtime
            .registry
            .register(ImageSpec::new("server:1", "server").with_size(1 << 20));
        cp.runtime.table.register("server", |ctx| {
            ctx.cancel.wait();
            Err("terminated".to_string())
        });
        cp
    }

    #[test]
    fn kubeconfig_written() {
        let cp = deploy_small();
        let text = cp.fs.read_str("/home/user/.hpk/kubeconfig").unwrap();
        assert!(text.contains("hpk-apiserver"));
        assert!(text.contains("token"));
        cp.shutdown();
    }

    #[test]
    fn deployment_end_to_end_through_slurm() {
        let cp = deploy_small();
        cp.kubectl_apply(
            "kind: Deployment\nmetadata:\n  name: web\nspec:\n  replicas: 2\n  selector:\n    matchLabels:\n      app: web\n  template:\n    metadata:\n      labels:\n        app: web\n    spec:\n      containers:\n      - name: main\n        image: server:1\n",
        )
        .unwrap();
        assert!(cp.wait_until(8000, |api| {
            api.list("Pod")
                .iter()
                .filter(|p| object::pod_phase(p) == "Running")
                .count()
                == 2
        }));
        // Both pods visible in squeue (compliance!).
        let q = cp.slurm.squeue();
        assert_eq!(q.len(), 2);
        assert!(q.iter().all(|j| j.comment.starts_with("default/web-")));
        // Scale down -> jobs cancelled.
        let mut dep = cp.api.get("Deployment", "default", "web").unwrap();
        dep.entry_map("spec").set("replicas", crate::yamlkit::Value::Int(0));
        cp.api.update(dep).unwrap();
        assert!(cp.wait_until(8000, |_| cp.slurm.squeue().is_empty()));
        cp.shutdown();
    }

    #[test]
    fn headless_service_resolves_to_hpk_pod_ips() {
        let cp = deploy_small();
        cp.kubectl_apply(
            "kind: Service\nmetadata:\n  name: db\nspec:\n  clusterIP: 10.96.0.1\n  selector:\n    app: db\n---\nkind: Pod\nmetadata:\n  name: db-0\n  labels:\n    app: db\nspec:\n  containers:\n  - name: main\n    image: server:1\n",
        )
        .unwrap();
        // Admission forced the service headless despite the explicit IP.
        let svc = cp.api.get("Service", "default", "db").unwrap();
        assert_eq!(svc.str_at("spec.clusterIP"), Some("None"));
        assert!(cp.wait_until(8000, |_| {
            !cp.dns.resolve("db.default.svc.cluster.local").is_empty()
        }));
        let ips = cp.dns.resolve("db");
        assert_eq!(ips.len(), 1);
        assert!(ips[0].to_string().starts_with("10.244."));
        // The same answer through the slice-aggregation surface, backed
        // by actual EndpointSlice shards (no whole Endpoints object).
        assert_eq!(cp.service_endpoints("default", "db"), vec![ips[0].to_string()]);
        assert!(!cp.api.list("EndpointSlice").is_empty());
        assert!(cp.api.list("Endpoints").is_empty());
        cp.shutdown();
    }
}
