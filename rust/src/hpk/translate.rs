//! Pod -> Slurm-script translation ("Workloads enter in YAML format
//! through the Kubernetes API endpoint and exit as Slurm scripts from
//! hpk-kubelet", Figure 2).
//!
//! The generated script uses only generic `#SBATCH` directives plus
//! `apptainer` command lines the [`super::executor`] interprets. Pod
//! resource requests map to `--cpus-per-task`/`--mem`; the
//! `slurm-job.hpk.io/flags` annotation is appended verbatim, which is
//! how Listing 2 scales MPI steps with `--ntasks`.

use crate::kube::object;
use crate::slurm::script::{apply_flags, render_script};
use crate::slurm::JobSpec;
use crate::yamlkit::Value;

/// The home-directory area where hpk-kubelet keeps per-pod state
/// (scripts, the IP handshake file) — HPK's "all configuration resides
/// in the user's home directory" requirement.
pub const HPK_DIR: &str = "/home/user/.hpk";

/// Per-pod state directory.
pub fn pod_dir(namespace: &str, name: &str) -> String {
    format!("{HPK_DIR}/{namespace}/{name}")
}

/// Quote a token for the generated script. Backslashes and backticks
/// force quoting too, so a bare token never needs unescaping —
/// [`crate::util::shlex::split`] round-trips every output exactly.
fn sh_quote(s: &str) -> String {
    if s.is_empty()
        || s.contains(|c: char| {
            c.is_whitespace() || c == '"' || c == '\'' || c == '$' || c == '\\' || c == '`'
        })
    {
        format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
    } else {
        s.to_string()
    }
}

/// Translate a pod manifest into a Slurm [`JobSpec`] whose script body
/// is a sequence of `apptainer` lines. Errors on malformed annotations.
pub fn pod_to_jobspec(pod: &Value) -> Result<JobSpec, String> {
    let ns = object::namespace(pod);
    let name = object::name(pod);
    let mut spec = JobSpec::new(&format!("hpk-{ns}-{name}"));
    spec.comment = format!("{ns}/{name}");

    // Resources: sum container requests; Slurm allocates per task.
    let (cpu_millis, mem_bytes) = object::pod_resource_totals(pod);
    spec.cpus_per_task = (((cpu_millis + 999) / 1000).max(1)) as u32;
    spec.mem_per_task = mem_bytes.max(64 << 20) as u64;

    // Script body: sandbox start + one exec line per container.
    let mut body = String::new();
    body.push_str(&format!("hpk_pod_dir={}\n", pod_dir(ns, name)));
    body.push_str("apptainer instance start --cni flannel --fakeroot hpk-pause parent\n");
    let containers = pod
        .path("spec.containers")
        .and_then(|c| c.as_seq())
        .ok_or("pod has no containers")?;
    if containers.is_empty() {
        return Err("pod has no containers".to_string());
    }
    for c in containers {
        let image = c
            .str_at("image")
            .ok_or("container has no image")?;
        let mut line = String::from("apptainer exec instance://parent --fakeroot");
        // Pod-spec env vars (downward fields are added by the executor).
        if let Some(items) = c.path("env").and_then(|e| e.as_seq()) {
            for item in items {
                if let (Some(k), Some(v)) = (
                    item.str_at("name"),
                    item.get("value").and_then(|v| v.coerce_string()),
                ) {
                    line.push_str(&format!(" --env {}", sh_quote(&format!("{k}={v}"))));
                }
            }
        }
        line.push(' ');
        line.push_str(&sh_quote(image));
        for arg in crate::kube::kubelet::container_args(c) {
            line.push(' ');
            line.push_str(&sh_quote(&arg));
        }
        body.push('\n');
        body.push_str(&line);
        body.push('\n');
    }
    spec.script = body;

    // Annotation pass-through (may override ntasks, time, partition...).
    if let Some(flags) = object::annotation(pod, super::annotations::SLURM_FLAGS) {
        apply_flags(&mut spec, flags)
            .map_err(|e| format!("bad {}: {e}", super::annotations::SLURM_FLAGS))?;
    }
    if let Some(mpi) = object::annotation(pod, super::annotations::MPI_FLAGS) {
        // Recorded for the MPI launcher inside the job.
        spec.env
            .push(("HPK_MPI_FLAGS".to_string(), mpi.to_string()));
    }
    // Gang (PodGroup) membership: namespaced so two groups with the
    // same name in different namespaces stay distinct gangs.
    if let Some(group) = object::annotation(pod, super::annotations::POD_GROUP) {
        let raw = object::annotation(pod, super::annotations::POD_GROUP_SIZE)
            .ok_or_else(|| {
                format!(
                    "{} requires {}",
                    super::annotations::POD_GROUP,
                    super::annotations::POD_GROUP_SIZE
                )
            })?;
        let size: u32 = raw.parse().ok().filter(|s| *s > 0).ok_or_else(|| {
            format!(
                "bad {} {raw:?}: expected a positive integer",
                super::annotations::POD_GROUP_SIZE
            )
        })?;
        spec = spec.with_gang(&format!("{ns}/{group}"), size);
    }
    if object::annotation(pod, super::annotations::PREEMPTIBLE) == Some("true") {
        spec = spec.with_preemptible();
    }
    Ok(spec)
}

/// Full script text (directives + body) — what lands in the user's home
/// directory and what `sbatch` receives.
pub fn pod_to_script(pod: &Value) -> Result<String, String> {
    Ok(render_script(&pod_to_jobspec(pod)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::yamlkit::parse_one;

    #[test]
    fn sh_quote_roundtrips_through_shlex_split() {
        assert_eq!(sh_quote("plain"), "plain");
        assert_eq!(sh_quote(r"a\b"), r#""a\\b""#);
        for token in ["plain", r"a\b", "with space", "a\"q", "pa$th", "tick`y"] {
            let line = format!("cmd {}", sh_quote(token));
            assert_eq!(
                crate::util::shlex::split(&line).unwrap(),
                vec!["cmd", token],
                "{line}"
            );
        }
    }

    fn pod_yaml() -> Value {
        parse_one(
            r#"
kind: Pod
metadata:
  name: tpcds-exec-1
  namespace: spark
  annotations:
    slurm-job.hpk.io/flags: >-
      --ntasks=4 --time=30
    slurm-job.hpk.io/mpi-flags: "-x LD_PRELOAD"
spec:
  containers:
  - name: exec
    image: spark:3.5
    command: ["spark-executor"]
    args: ["--cores", "1"]
    env:
    - name: DRIVER_URL
      value: spark-driver.spark
    resources:
      requests:
        cpu: 1
        memory: 8Gi
"#,
        )
        .unwrap()
    }

    #[test]
    fn resources_and_identity_forwarded() {
        let spec = pod_to_jobspec(&pod_yaml()).unwrap();
        assert_eq!(spec.comment, "spark/tpcds-exec-1");
        assert_eq!(spec.cpus_per_task, 1);
        assert_eq!(spec.mem_per_task, 8 << 30);
    }

    #[test]
    fn annotation_flags_applied() {
        let spec = pod_to_jobspec(&pod_yaml()).unwrap();
        assert_eq!(spec.ntasks, 4);
        assert_eq!(spec.time_limit_ms, 30 * 60_000);
        assert_eq!(
            spec.env,
            vec![("HPK_MPI_FLAGS".to_string(), "-x LD_PRELOAD".to_string())]
        );
    }

    #[test]
    fn script_contains_apptainer_lines() {
        let script = pod_to_script(&pod_yaml()).unwrap();
        assert!(script.contains("#SBATCH --job-name=hpk-spark-tpcds-exec-1"));
        assert!(script.contains("#SBATCH --comment=spark/tpcds-exec-1"));
        assert!(script.contains("apptainer instance start --cni flannel"));
        assert!(script.contains("apptainer exec instance://parent --fakeroot"));
        assert!(script.contains("spark:3.5"));
        assert!(script.contains("--env DRIVER_URL=spark-driver.spark"));
        assert!(script.contains("spark-executor --cores 1"));
    }

    #[test]
    fn script_reparses_as_slurm_job() {
        let script = pod_to_script(&pod_yaml()).unwrap();
        let spec = crate::slurm::script::parse_script(&script).unwrap();
        assert_eq!(spec.ntasks, 4);
        assert_eq!(spec.comment, "spark/tpcds-exec-1");
    }

    #[test]
    fn pod_group_annotations_become_gang_spec() {
        let mut pod = pod_yaml();
        pod.entry_map("metadata")
            .entry_map("annotations")
            .set(super::super::annotations::POD_GROUP, Value::from("ring"));
        pod.entry_map("metadata")
            .entry_map("annotations")
            .set(super::super::annotations::POD_GROUP_SIZE, Value::from("3"));
        pod.entry_map("metadata")
            .entry_map("annotations")
            .set(super::super::annotations::PREEMPTIBLE, Value::from("true"));
        let spec = pod_to_jobspec(&pod).unwrap();
        assert_eq!(spec.gang_id.as_deref(), Some("spark/ring"));
        assert_eq!(spec.gang_size, 3);
        assert!(spec.requeue, "gang pods requeue as a group");
        assert!(spec.preemptible);
    }

    #[test]
    fn pod_group_without_size_is_an_error() {
        let mut pod = pod_yaml();
        pod.entry_map("metadata")
            .entry_map("annotations")
            .set(super::super::annotations::POD_GROUP, Value::from("ring"));
        assert!(pod_to_jobspec(&pod).is_err());
    }

    #[test]
    fn pod_group_size_zero_is_an_error() {
        // A gang of zero would admit instantly and never place a pod.
        let mut pod = pod_yaml();
        pod.entry_map("metadata")
            .entry_map("annotations")
            .set(super::super::annotations::POD_GROUP, Value::from("ring"));
        pod.entry_map("metadata")
            .entry_map("annotations")
            .set(super::super::annotations::POD_GROUP_SIZE, Value::from("0"));
        let e = pod_to_jobspec(&pod).unwrap_err();
        assert!(e.contains("\"0\""), "error names the bad value: {e}");
    }

    #[test]
    fn bad_annotation_is_an_error() {
        let mut pod = pod_yaml();
        pod.entry_map("metadata")
            .entry_map("annotations")
            .set(super::super::annotations::SLURM_FLAGS, Value::from("--bogus=1"));
        assert!(pod_to_jobspec(&pod).is_err());
    }

    #[test]
    fn no_containers_rejected() {
        let pod = parse_one("kind: Pod\nmetadata:\n  name: x\nspec:\n  containers: []\n").unwrap();
        assert!(pod_to_jobspec(&pod).is_err());
    }
}
