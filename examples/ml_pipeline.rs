//! SS4.3 end-to-end driver: the distributed ML pipeline.
//!
//!     make artifacts && cargo run --release --example ml_pipeline
//!
//! Reproduces the paper's Kubeflow workflow on HPK, all layers
//! composing: an Argo workflow ingests the dataset; TFJobs train three
//! classifier variants with synchronous 2-worker data-parallel SGD
//! (each worker's grad step is the AOT-compiled JAX graph whose dense
//! layers are the L1 Pallas matmul kernel, executed via PJRT from
//! Rust); the best model by held-out accuracy is deployed as an
//! inference service behind a headless Kubernetes service, and queries
//! are answered through CoreDNS + the pod fabric. Loss curves and the
//! selection table print at the end (recorded in EXPERIMENTS.md).

use hpk::operators::training::{self, operator::tfjob_manifest};
use hpk::testbed;
use std::time::Instant;

const VARIANTS: &[&str] = &["mlp-small", "mlp-medium", "mlp-large"];
const WORKERS: usize = 2;
const STEPS: u64 = 200;

fn main() {
    println!("== Distributed ML pipeline on HPK (SS4.3) ==\n");
    let tb = testbed::deploy(4, 8);
    assert!(
        tb.pjrt.is_some(),
        "artifacts/ missing — run `make artifacts` first"
    );

    // ---- Stage 1: data ingestion via an Argo workflow step. ----------
    println!("--> workflow stage 1: data ingestion");
    tb.cp
        .kubectl_apply(
            r#"kind: Workflow
metadata:
  name: ingest
spec:
  entrypoint: main
  templates:
  - name: main
    dag:
      tasks:
      - {name: ingest, template: ingest}
  - name: ingest
    container:
      image: data-ingest:latest
      env:
      - {name: SHARDS, value: "8"}
      - {name: SAMPLES_PER_SHARD, value: "512"}
      - {name: DATA_DIR, value: /home/user/datasets/fmnist}
"#,
        )
        .unwrap();
    assert!(tb.cp.wait_until(60_000, |api| {
        api.get("Workflow", "default", "ingest")
            .ok()
            .and_then(|w| w.str_at("status.phase").map(|p| p == "Succeeded"))
            .unwrap_or(false)
    }));
    let shards = tb.cp.fs.list("/home/user/datasets/fmnist").len();
    println!("    {shards} dataset files materialized\n");

    // ---- Stage 2: train the three variants as TFJobs. -----------------
    println!(
        "--> workflow stage 2: distributed training ({WORKERS} workers x {STEPS} steps each)"
    );
    let t0 = Instant::now();
    for v in VARIANTS {
        tb.cp
            .kubectl_apply(&tfjob_manifest(
                &format!("train-{v}"),
                "default",
                v,
                WORKERS,
                STEPS,
                0.15,
                &format!("/home/user/models/{v}"),
            ))
            .unwrap();
    }
    for v in VARIANTS {
        let name = format!("train-{v}");
        assert!(
            tb.cp.wait_until(600_000, |api| {
                api.get("TFJob", "default", &name)
                    .ok()
                    .and_then(|j| j.str_at("status.state").map(|s| s == "Succeeded"))
                    .unwrap_or(false)
            }),
            "{name} did not succeed"
        );
        println!("    {name}: Succeeded");
    }
    println!("    all variants trained in {:.2?}\n", t0.elapsed());

    // ---- Stage 3: model selection on held-out accuracy. ---------------
    println!("--> workflow stage 3: model selection");
    println!(
        "    {:<12} {:>10} {:>10} {:>12} {:>14}",
        "variant", "params", "nll", "accuracy", "loss 1st->last"
    );
    let mut best: Option<(&str, f32)> = None;
    for v in VARIANTS {
        let metrics = tb
            .cp
            .fs
            .read_str(&format!("/home/user/models/{v}/metrics.txt"))
            .unwrap();
        let acc: f32 = metrics
            .split("accuracy=")
            .nth(1)
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        let nll: f32 = metrics
            .split("nll=")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        let csv = tb
            .cp
            .fs
            .read_str(&format!("/home/user/models/{v}/loss.csv"))
            .unwrap();
        let losses: Vec<f32> = csv
            .lines()
            .skip(1)
            .filter_map(|l| l.split(',').nth(1)?.parse().ok())
            .collect();
        println!(
            "    {:<12} {:>10} {:>10.4} {:>11.1}% {:>8.3} -> {:.3}",
            v,
            hpk::workloads::trainer::param_count(v),
            nll,
            acc * 100.0,
            losses.first().unwrap(),
            losses.last().unwrap()
        );
        if best.map(|(_, a)| acc > a).unwrap_or(true) {
            best = Some((v, acc));
        }
    }
    let (winner, acc) = best.unwrap();
    println!("    selected: {winner} ({:.1}% held-out accuracy)\n", acc * 100.0);

    // ---- Stage 4: deploy the winner as an inference service. ----------
    println!("--> workflow stage 4: inference service");
    tb.cp
        .kubectl_apply(&format!(
            r#"kind: Deployment
metadata:
  name: classifier
spec:
  replicas: 1
  selector:
    matchLabels:
      app: classifier
  template:
    metadata:
      labels:
        app: classifier
    spec:
      containers:
      - name: serving
        image: tf-serving:latest
        env:
        - {{name: MODEL_VARIANT, value: {winner}}}
        - {{name: MODEL_PATH, value: /home/user/models/{winner}/weights.bin}}
---
kind: Service
metadata:
  name: classifier
spec:
  selector:
    app: classifier
  ports:
  - port: 8501
"#
        ))
        .unwrap();
    assert!(tb.cp.wait_until(60_000, |_| {
        tb.cp
            .dns
            .resolve_one("classifier")
            .map(|ip| tb.cp.runtime.fabric.is_bound(ip, training::SERVING_PORT))
            .unwrap_or(false)
    }));
    let ip = tb.cp.dns.resolve_one("classifier").unwrap();
    let server = tb
        .cp
        .runtime
        .fabric
        .connect::<training::InferenceServer>(ip, training::SERVING_PORT)
        .unwrap();
    let (x, y) = hpk::workloads::dataset::synthetic_batch(512, 123_456);
    let t_inf = Instant::now();
    let predictions = server.classify(&x).unwrap();
    let correct = predictions
        .iter()
        .zip(y.as_i32())
        .filter(|(p, t)| p == t)
        .count();
    println!(
        "    served 512 queries in {:.2?} via {ip}:8501 -> accuracy {:.1}%\n",
        t_inf.elapsed(),
        correct as f32 * 100.0 / 512.0
    );

    println!("Slurm accounting: {} jobs total (ingest + {} trainers + serving)",
        tb.cp.slurm.sacct().len() + tb.cp.slurm.squeue().len(),
        VARIANTS.len() * WORKERS,
    );
    tb.shutdown();
    println!("== pipeline complete ==");
}
