//! Serving under load: the closed request loop, end to end.
//!
//!     cargo run --release --example serving_load
//!
//! A model-serving Deployment sits behind a headless Service with a
//! HorizontalPodAutoscaler targeting 25 req/s per pod. A simulated
//! client fleet drives a step curve through CoreDNS and the
//! EndpointSlice-backed service dataplane; request metrics feed the
//! autoscaler, which scales the Deployment out to its max under the
//! step and back in once the load (and the stabilization window)
//! passes. Every pod is a Slurm job throughout.
//!
//! With PJRT artifacts built (`make artifacts`) the backends are real
//! `tf-serving` containers loading weights from shared storage;
//! without them a pause container stands in — the control loop under
//! test (traffic -> metrics -> HPA -> Deployment -> Slurm) is the
//! same either way.

use hpk::kube::object;
use hpk::testbed;
use hpk::traffic::{Curve, LoadGen};

/// Per-pod request-rate target the HPA scales against.
const TARGET_RPS: f64 = 25.0;
const MAX_REPLICAS: i64 = 5;

fn running(api: &hpk::kube::ApiServer) -> usize {
    api.list("Pod")
        .iter()
        .filter(|p| object::pod_phase(p) == "Running")
        .count()
}

fn replicas(api: &hpk::kube::ApiServer) -> i64 {
    api.get("Deployment", "default", "model")
        .ok()
        .and_then(|d| d.i64_at("spec.replicas"))
        .unwrap_or(0)
}

fn main() {
    println!("== HPK serving under load ==");
    println!("deploying HPK on a 3-node x 8-cpu simulated Slurm cluster\n");
    let tb = testbed::deploy(3, 8);
    let clock = tb.cp.cluster.clock.clone();

    // Backend image: real tf-serving when artifacts are built.
    let container = if tb.pjrt.is_some() {
        let params = hpk::workloads::trainer::init_params_rust("mlp-small", 42);
        let bytes = hpk::operators::training::trainer_encode(&params);
        tb.cp
            .fs
            .write("/home/user/models/demo/weights.bin", bytes)
            .expect("write weights");
        println!("backends: tf-serving:latest (PJRT artifacts found)");
        "        image: tf-serving:latest
        env:
        - name: MODEL_VARIANT
          value: mlp-small
        - name: MODEL_PATH
          value: /home/user/models/demo/weights.bin
"
    } else {
        println!("backends: pause:3.9 stand-in (no PJRT artifacts)");
        "        image: pause:3.9
"
    };

    println!(
        "--> kubectl apply deployment(model) + service(model) + hpa(target {TARGET_RPS} req/s, max {MAX_REPLICAS})"
    );
    tb.cp
        .kubectl_apply(&format!(
            r#"kind: Deployment
metadata:
  name: model
spec:
  replicas: 1
  selector:
    matchLabels:
      app: model
  template:
    metadata:
      labels:
        app: model
    spec:
      containers:
      - name: serving
{container}        resources:
          requests:
            cpu: 1
---
kind: Service
metadata:
  name: model
spec:
  selector:
    app: model
  ports:
  - port: 8501
---
kind: HorizontalPodAutoscaler
apiVersion: autoscaling/v2
metadata:
  name: model
spec:
  scaleTargetRef:
    kind: Deployment
    name: model
  minReplicas: 1
  maxReplicas: {MAX_REPLICAS}
  targetRequestsPerSecond: {TARGET_RPS}
  stabilizationWindowMs: 30000
"#
        ))
        .expect("apply");

    assert!(tb.cp.wait_until(60_000, |api| {
        running(api) == 1 && !tb.cp.service_endpoints("default", "model").is_empty()
    }));
    println!("1 backend Running; endpoints published\n");

    let mut lg = LoadGen::new(
        &tb.cp.api,
        tb.cp.dns.clone(),
        tb.cp.proxy.clone(),
        tb.cp.metrics.clone(),
        clock.clone(),
        "model",
    )
    .with_seed(11);

    // Phase A: steady low load, well under target -> no scaling, and a
    // hard zero-drop guarantee (nothing churns, so nothing is stale).
    println!("--> phase A: 8 req/s for 20 simulated s (below target)");
    let run_a = lg.run_for(&Curve::Constant { rps: 8.0 }, 20_000);
    println!(
        "    served={} dropped={} no_backend={}",
        run_a.served, run_a.dropped, run_a.no_backend
    );
    assert!(run_a.served > 0, "no requests served: {run_a:?}");
    assert_eq!(run_a.dropped, 0, "dropped requests at steady state: {run_a:?}");
    assert_eq!(run_a.no_backend, 0);
    assert_eq!(replicas(&tb.cp.api), 1, "hpa scaled a below-target service");

    // Phase B: the step. 120 req/s against one pod blows through the
    // target; the autoscaler reacts off the metrics push.
    println!("\n--> phase B: step to 120 req/s");
    let t0 = clock.now_ms();
    let handle = std::thread::spawn(move || {
        let run = lg.run_for(&Curve::Constant { rps: 120.0 }, 60_000);
        (lg, run)
    });
    assert!(
        tb.cp.wait_until(60_000, |api| running(api) >= 2),
        "hpa never scaled out"
    );
    let reaction_ms = clock.now_ms() - t0;
    println!("    scale-out reaction: {reaction_ms} simulated ms to a second Running pod");
    let (mut lg, run_b) = handle.join().unwrap();
    assert_eq!(run_b.no_backend, 0);

    // Keep the high rate flowing until the autoscaler converges at its
    // max (each round is more traffic, which is more metrics pushes).
    let mut rounds = 0;
    while running(&tb.cp.api) < MAX_REPLICAS as usize && rounds < 40 {
        lg.run_for(&Curve::Constant { rps: 120.0 }, 5_000);
        rounds += 1;
    }
    assert_eq!(
        running(&tb.cp.api),
        MAX_REPLICAS as usize,
        "hpa did not converge at maxReplicas"
    );
    assert_eq!(replicas(&tb.cp.api), MAX_REPLICAS, "spec.replicas exceeded max");
    println!("    converged at {MAX_REPLICAS} replicas (maxReplicas respected)");
    println!("    squeue now holds {} serving jobs", tb.cp.slurm.squeue().len());

    // Steady state at scale: the full 120 req/s spread across the
    // fleet, zero drops, per-pod rate back under target.
    let steady = lg.run_for(&Curve::Constant { rps: 120.0 }, 20_000);
    println!(
        "    steady at scale: served={} dropped={} no_backend={}",
        steady.served, steady.dropped, steady.no_backend
    );
    assert_eq!(steady.dropped, 0, "dropped requests at steady state: {steady:?}");
    assert_eq!(steady.no_backend, 0);
    let ips: Vec<String> = tb
        .cp
        .api
        .list("Pod")
        .iter()
        .filter(|p| object::pod_phase(p) == "Running")
        .filter_map(|p| p.str_at("status.podIP").map(str::to_string))
        .collect();
    let avg = ips.iter().map(|ip| tb.cp.metrics.rps(ip)).sum::<f64>() / ips.len() as f64;
    println!("    per-pod rate: {avg:.1} req/s (target {TARGET_RPS})");
    assert!(avg < TARGET_RPS * 1.3, "per-pod rate did not re-converge: {avg}");

    // Phase C: load falls away; after the stabilization window the
    // autoscaler walks the fleet back to one replica. The drops here
    // are the stale-endpoint window of the pods being torn down.
    println!("\n--> phase C: load drops to 5 req/s; waiting for scale-in");
    let run_c = lg.run_for(&Curve::Constant { rps: 5.0 }, 30_000);
    assert_eq!(run_c.no_backend, 0);
    assert!(
        tb.cp.wait_until(120_000, |api| replicas(api) == 1 && running(api) == 1),
        "hpa never scaled back in"
    );
    println!(
        "    scaled back to 1 replica ({} requests hit the teardown window)",
        run_c.dropped
    );

    let totals = lg.stats();
    println!(
        "\ntotals: served={} dropped={} no_backend={}",
        totals.served, totals.dropped, totals.no_backend
    );
    assert_eq!(totals.no_backend, 0, "service was never without endpoints");
    let hpa = tb.cp.api.get("HorizontalPodAutoscaler", "default", "model").unwrap();
    println!(
        "hpa status: currentReplicas={} desiredReplicas={}",
        hpa.i64_at("status.currentReplicas").unwrap_or(-1),
        hpa.i64_at("status.desiredReplicas").unwrap_or(-1),
    );

    tb.shutdown();
    println!("== serving_load complete ==");
}
