//! Quickstart: boot HPK, deploy a microservice, watch it appear in the
//! Slurm queue, scale it, resolve it through DNS, tear it down.
//!
//!     cargo run --release --example quickstart
//!
//! This is the paper's core pitch in one file: an *unmodified*
//! Kubernetes workflow (Deployment + headless Service) executing as
//! Slurm jobs under the HPC center's normal accounting.

use hpk::kube::object;
use hpk::testbed;

fn main() {
    println!("== HPK quickstart ==");
    println!("deploying HPK on a 4-node x 8-cpu simulated Slurm cluster\n");
    let tb = testbed::deploy(4, 8);

    // 1. kubectl apply a Deployment + Service, exactly as in the Cloud.
    println!("--> kubectl apply deployment(web, replicas=3) + service(web)");
    tb.cp
        .kubectl_apply(
            r#"kind: Deployment
metadata:
  name: web
spec:
  replicas: 3
  selector:
    matchLabels:
      app: web
  template:
    metadata:
      labels:
        app: web
    spec:
      containers:
      - name: main
        image: pause:3.9
        resources:
          requests:
            cpu: 2
            memory: 1Gi
---
kind: Service
metadata:
  name: web
spec:
  selector:
    app: web
  ports:
  - port: 80
"#,
        )
        .expect("apply");

    // 2. Pods come up through Slurm + Apptainer.
    assert!(tb.cp.wait_until(60_000, |api| {
        api.list("Pod")
            .iter()
            .filter(|p| object::pod_phase(p) == "Running")
            .count()
            == 3
    }));
    println!("\nsqueue (the HPC center's view -- compliance):");
    for j in tb.cp.slurm.squeue() {
        println!(
            "  job {:>3}  {:<24} {:<3} cpus={} comment={}",
            j.job_id,
            j.name,
            j.state.code(),
            j.alloc_cpus,
            j.comment
        );
    }
    println!("\nsinfo:");
    for (node, used, total, state) in tb.cp.slurm.sinfo() {
        println!("  {node}: {used}/{total} cpus [{state}]");
    }

    // 3. Service discovery: headless, straight to pod IPs.
    let svc = tb.cp.api.get("Service", "default", "web").unwrap();
    println!(
        "\nservice web: clusterIP={} (admission forced headless)",
        svc.str_at("spec.clusterIP").unwrap_or("?")
    );
    tb.cp.wait_until(30_000, |_| tb.cp.dns.resolve("web").len() == 3);
    println!("dns web.default.svc.cluster.local -> {:?}", tb.cp.dns.resolve("web"));

    // 4. The generated artifacts live in the user's home dir.
    let script = tb
        .cp
        .fs
        .list("/home/user/.hpk/default")
        .into_iter()
        .find(|p| p.ends_with("job.sbatch"))
        .expect("a generated sbatch script");
    println!("\ngenerated Slurm script ({script}):");
    for line in tb.cp.fs.read_str(&script).unwrap().lines().take(10) {
        println!("  | {line}");
    }

    // 5. Scale up, then delete; Slurm queue follows.
    println!("\n--> kubectl scale deployment web --replicas=5");
    let mut dep = tb.cp.api.get("Deployment", "default", "web").unwrap();
    dep.entry_map("spec").set("replicas", hpk::Value::Int(5));
    tb.cp.api.update(dep).unwrap();
    tb.cp.wait_until(60_000, |_| tb.cp.slurm.squeue().len() == 5);
    println!("squeue now has {} jobs", tb.cp.slurm.squeue().len());

    println!("\n--> kubectl delete deployment web");
    tb.cp.api.delete("Deployment", "default", "web").unwrap();
    tb.cp.wait_until(60_000, |_| tb.cp.slurm.squeue().is_empty());
    println!("queue drained; {} pod IPs leaked", tb.cp.runtime.cni.live_count());

    println!("\naccounting (sacct) saw {} jobs total", tb.cp.slurm.sacct().len());
    tb.shutdown();
    println!("== quickstart complete ==");
}
