//! SS4.2 / Listing 2: an Argo workflow fanning out NAS EP MPI steps,
//! each scaled with a different Slurm `--ntasks` via the HPK
//! annotation pass-through.
//!
//!     cargo run --release --example argo_mpi

use hpk::testbed;
use std::time::Instant;

fn main() {
    println!("== Argo + MPI parameter sweep on HPK (SS4.2, Listing 2) ==\n");
    let tb = testbed::deploy(4, 8);

    let sweep = [2u32, 4, 8, 16];
    let items = sweep
        .iter()
        .map(|n| format!("        - {n}"))
        .collect::<Vec<_>>()
        .join("\n");
    let wf = format!(
        r#"kind: Workflow
metadata:
  name: npb-with-mpi
spec:
  entrypoint: npb-with-mpi
  templates:
  - name: npb-with-mpi
    dag:
      tasks:
      - name: A
        template: npb
        arguments:
          parameters:
          - {{name: cpus, value: "{{{{item}}}}"}}
        withItems:
{items}
  - name: npb
    metadata:
      annotations:
        slurm-job.hpk.io/flags: >-
          --ntasks={{{{inputs.parameters.cpus}}}}
        slurm-job.hpk.io/mpi-flags: "..."
    inputs:
      parameters:
      - name: cpus
    container:
      image: mpi-npb:latest
      command: ["ep.W.{{{{inputs.parameters.cpus}}}}"]
      env:
      - name: EP_OUT_DIR
        value: "/home/user/ep-results/{{{{inputs.parameters.cpus}}}}"
"#
    );
    println!("--> argo submit (4 parallel EP steps, ntasks = {sweep:?})");
    let t0 = Instant::now();
    tb.cp.kubectl_apply(&wf).unwrap();
    let ok = tb.cp.wait_until(180_000, |api| {
        api.get("Workflow", "default", "npb-with-mpi")
            .ok()
            .and_then(|w| w.str_at("status.phase").map(|p| p == "Succeeded"))
            .unwrap_or(false)
    });
    assert!(ok, "workflow failed");
    println!("    workflow Succeeded in {:.2?}\n", t0.elapsed());

    println!("per-step results (from Slurm accounting + rank tallies):");
    let acct = tb.cp.slurm.sacct();
    for n in sweep {
        let rec = acct
            .iter()
            .filter(|r| r.comment.contains("npb-with-mpi"))
            .find(|r| r.alloc_cpus == n)
            .expect("step record");
        let elapsed = rec.end_ms - rec.start_ms;
        let mut accepted = 0u64;
        let mut pairs = 0u64;
        for rank in 0..n {
            let line = tb
                .cp
                .fs
                .read_str(&format!("/home/user/ep-results/{n}/rank-{rank}.txt"))
                .unwrap();
            let mut parts = line.split_whitespace();
            accepted += parts.next().unwrap().parse::<u64>().unwrap();
            pairs += parts.next().unwrap().parse::<u64>().unwrap();
        }
        println!(
            "  ntasks={n:>2}  sim-elapsed={elapsed:>6} ms  pairs={pairs}  accepted={accepted}  (acc/pairs={:.4})",
            accepted as f64 / pairs as f64
        );
    }
    println!("\n(the accepted totals are identical across ntasks — the sweep");
    println!(" splits one deterministic sample space, so the physics agrees)");
    tb.shutdown();
    println!("== done ==");
}
