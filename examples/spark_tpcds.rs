//! SS4.1: Spark TPC-DS on HPK — the full paper flow.
//!
//!     cargo run --release --example spark_tpcds
//!
//! 1. helm install spark-operator + MinIO (service `spark-k8s-data`).
//! 2. Submit the data-generation SparkApplication.
//! 3. Submit the benchmark SparkApplication (q3/q55/q7) with the
//!    executor count from Listing 1, and print the query results.

use hpk::operators::spark::operator::spark_application_manifest;
use hpk::testbed;
use std::time::Instant;

fn wait_completed(tb: &testbed::Testbed, app: &str) {
    let ok = tb.cp.wait_until(120_000, |api| {
        api.get("SparkApplication", "default", app)
            .ok()
            .and_then(|a| {
                a.str_at("status.applicationState.state")
                    .map(|s| s == "COMPLETED" || s == "FAILED")
            })
            .unwrap_or(false)
    });
    let state = tb
        .cp
        .api
        .get("SparkApplication", "default", app)
        .ok()
        .and_then(|a| {
            a.str_at("status.applicationState.state").map(String::from)
        })
        .unwrap_or_default();
    assert!(ok && state == "COMPLETED", "{app}: state={state}");
}

fn main() {
    println!("== Spark TPC-DS on HPK (SS4.1) ==\n");
    let tb = testbed::deploy(4, 8);

    println!("--> helm install minio (service name spark-k8s-data)");
    tb.install_minio("spark-k8s-data").expect("minio up");

    let scale = 1;
    let partitions = 8;
    let executors = 3; // Listing 1: 3 executors x 1 core

    println!("--> submit SparkApplication tpcds-data-generation (sf={scale}, {partitions} partitions, {executors} executors)");
    let t0 = Instant::now();
    tb.cp
        .kubectl_apply(&spark_application_manifest(
            "tpcds-benchmark-data-generation-1g",
            "default",
            "datagen",
            scale,
            partitions,
            "",
            executors,
            1,
            "8000m",
        ))
        .unwrap();
    wait_completed(&tb, "tpcds-benchmark-data-generation-1g");
    println!("    datagen COMPLETED in {:.2?}", t0.elapsed());

    let store = tb.object_store("spark-k8s-data").unwrap();
    println!(
        "    store_sales: {} partitions, {:.1} MiB in MinIO",
        store.list("spark", "tpcds/sf1/store_sales/").len(),
        store.bucket_size("spark") as f64 / (1 << 20) as f64
    );

    println!("\n--> submit SparkApplication tpcds-benchmark (q3, q55, q7)");
    let t1 = Instant::now();
    tb.cp
        .kubectl_apply(&spark_application_manifest(
            "tpcds-benchmark-1g",
            "default",
            "benchmark",
            scale,
            partitions,
            "q3,q55,q7",
            executors,
            1,
            "8000m",
        ))
        .unwrap();
    wait_completed(&tb, "tpcds-benchmark-1g");
    println!("    benchmark COMPLETED in {:.2?}\n", t1.elapsed());

    for q in ["q3", "q55", "q7"] {
        let csv = store
            .get("spark", &format!("results/tpcds-benchmark-1g/{q}.csv"))
            .unwrap();
        let text = String::from_utf8_lossy(&csv);
        println!("{q} (first 6 rows):");
        for line in text.lines().take(6) {
            println!("  {line}");
        }
        println!();
    }

    println!("Slurm accounting for the run:");
    let mut cpu_ms = 0u64;
    for r in tb.cp.slurm.sacct() {
        cpu_ms += r.cpu_ms();
    }
    println!(
        "  {} jobs, {:.1} cpu-seconds (simulated) billed to the user",
        tb.cp.slurm.sacct().len(),
        cpu_ms as f64 / 1000.0
    );
    tb.shutdown();
    println!("== done ==");
}
