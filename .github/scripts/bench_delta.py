#!/usr/bin/env python3
"""Print a markdown per-metric delta table between two bench JSON files.

Usage: bench_delta.py <previous.json> <current.json>

Warn-only: regressions get a warning marker in the table, but the exit
code is always 0 — the perf trajectory is made visible per-PR without
hard-failing on noisy runners. Metric direction is inferred from the
name suffix (`_ms`/`_us`/`_bytes*`/`*wakeups`/`*writes`/`_dropped`/
`_no_backend` are lower-is-better, `_per_s`/`_rate`/`_speedup` are
higher-is-better; everything else is reported without judgement).
"""

import json
import sys

# Relative change beyond which a regression is flagged (warn-only).
WARN_THRESHOLD = 0.25

LOWER_IS_BETTER = (
    "_ms",
    "_us",
    "_bytes",
    "_bytes_written",
    "_wakeups",
    "_writes",
    "_dropped",
    "_no_backend",
)
# The suffix rule auto-classifies new tiers — e.g. E8y's YAML-ingestion
# metrics (e8y_parse_mb_per_s, e8y_apply_objs_per_s) are both
# higher-is-better by suffix alone.
HIGHER_IS_BETTER = ("_per_s", "_rate", "_speedup")

# Bench configuration / baseline metrics, not costs the code pays:
# growing these (e.g. a bigger E5.3d service) is not a regression.
# e6s_place_linear_per_s is the frozen first-fit reference the indexed
# path is compared against — its drift is runner noise, not a signal.
NEUTRAL = {
    "e53c_idle_window_ms",
    "e53d_endpoints",
    "e53d_shards",
    "e53d_whole_object_bytes",
    "e6s_nodes",
    "e6s_pods",
    "e6s_place_linear_per_s",
    # E6v's scaled rate is pinned at time_scale by construction — the
    # driven rate and the speedup ratio carry the signal.
    "e6v_trace_sim_ms",
    "e6v_scaled_replay_rate",
    # E7g.C's requeued-member count describes the failure scenario's
    # shape (gangs touching the failed node); the sweep latency next to
    # it carries the signal.
    "e7g_requeued_members",
}


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def direction(name):
    if name in NEUTRAL:
        return None
    if name.endswith(HIGHER_IS_BETTER):
        return "higher"
    if name.endswith(LOWER_IS_BETTER):
        return "lower"
    return None


def main():
    prev_path, cur_path = sys.argv[1], sys.argv[2]
    prev, cur = load(prev_path), load(cur_path)
    print("### Bench delta vs previous main run\n")
    if cur is None:
        print(f"_current bench JSON missing or unreadable ({cur_path})_")
        return
    if prev is None:
        print(f"_no previous artifact ({prev_path}) — first run, or download failed_")
        return
    print("| metric | previous | current | delta | |")
    print("|---|---:|---:|---:|---|")
    warned = False
    for name in sorted(cur):
        cur_v = cur[name]
        prev_v = prev.get(name)
        if not isinstance(cur_v, (int, float)) or name == "smoke":
            continue
        if not isinstance(prev_v, (int, float)):
            print(f"| {name} | — | {cur_v:.3g} | new | |")
            continue
        if prev_v == 0:
            rel = 0.0 if cur_v == 0 else float("inf")
        else:
            rel = (cur_v - prev_v) / abs(prev_v)
        flag = ""
        d = direction(name)
        if d == "lower" and rel > WARN_THRESHOLD:
            flag, warned = "⚠️ regression", True
        elif d == "higher" and rel < -WARN_THRESHOLD:
            flag, warned = "⚠️ regression", True
        print(f"| {name} | {prev_v:.3g} | {cur_v:.3g} | {rel:+.1%} | {flag} |")
    print()
    if warned:
        print(
            f"_⚠️ at least one metric moved more than {WARN_THRESHOLD:.0%} in the "
            "wrong direction (warn-only, smoke-mode numbers are noisy)_"
        )
    else:
        print("_no metric regressed beyond the warn threshold_")


if __name__ == "__main__":
    main()
    sys.exit(0)
