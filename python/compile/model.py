"""L2: the SS4.3 image-classifier compute graph (fwd/bwd/SGD) in JAX.

The paper's distributed-training experiment trains several classifier
variants on Fashion-MNIST via TensorFlow's MultiWorkerMirroredStrategy.
Our reproduction keeps the same *training semantics* (synchronous
data-parallel SGD: every worker computes gradients on its shard, gradients
are all-reduced, every worker applies the identical update) but expresses
the per-worker compute as a JAX graph whose dense layers run through the
L1 Pallas matmul kernel (see kernels/matmul.py).

The graph is AOT-lowered by aot.py; at runtime the Rust training operator
(``operators::training``) executes the compiled artifacts via PJRT and
performs the all-reduce across simulated worker pods itself. Python never
runs on the request path.

Three variants reproduce the paper's "train several different models and
pick the best" workflow:

  ===========  =================  ============
  variant      hidden layers      ~parameters
  ===========  =================  ============
  mlp-small    (256, 128)         235k
  mlp-medium   (512, 256)         535k
  mlp-large    (1024, 512)        1.3M
  ===========  =================  ============
"""

import jax
import jax.numpy as jnp

from .kernels import matmul_bias_act

INPUT_DIM = 28 * 28
NUM_CLASSES = 10

VARIANTS = {
    "mlp-small": (256, 128),
    "mlp-medium": (512, 256),
    "mlp-large": (1024, 512),
}


def param_shapes(variant):
    """[(name, shape), ...] for a variant, in positional-argument order."""
    h1, h2 = VARIANTS[variant]
    return [
        ("w1", (INPUT_DIM, h1)),
        ("b1", (h1,)),
        ("w2", (h1, h2)),
        ("b2", (h2,)),
        ("w3", (h2, NUM_CLASSES)),
        ("b3", (NUM_CLASSES,)),
    ]


def init_params(variant, key):
    """He-initialised parameters as a flat tuple (test/compile-time only;
    the Rust runtime does its own deterministic init with the same scheme).
    """
    params = []
    for name, shape in param_shapes(variant):
        key, sub = jax.random.split(key)
        if name.startswith("w"):
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return tuple(params)


def apply(w1, b1, w2, b2, w3, b3, x):
    """Forward pass: logits for a batch of flattened 28x28 images."""
    h = matmul_bias_act(x, w1, b1, "relu")
    h = matmul_bias_act(h, w2, b2, "relu")
    return matmul_bias_act(h, w3, b3, "none")


def _log_softmax(logits):
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    return shifted - jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))


def loss_fn(w1, b1, w2, b2, w3, b3, x, y):
    """Mean softmax cross-entropy over the batch; y is int32 labels."""
    logp = _log_softmax(apply(w1, b1, w2, b2, w3, b3, x))
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def grad_step(w1, b1, w2, b2, w3, b3, x, y):
    """One gradient evaluation: returns (g_w1, g_b1, ..., g_b3, loss).

    This is the per-worker unit of SS4.3's synchronous training: each
    worker runs grad_step on its shard; the coordinator all-reduces the
    gradients and applies the SGD update (mirroring
    MultiWorkerMirroredStrategy, where the update is replicated). Keeping
    the update outside the artifact lets the Rust side scale the averaged
    gradient by the learning-rate schedule without recompiling.
    """
    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2, 3, 4, 5))(
        w1, b1, w2, b2, w3, b3, x, y
    )
    return (*grads, loss)


def train_step(w1, b1, w2, b2, w3, b3, x, y, lr):
    """Fused single-worker step: SGD update applied in-graph.

    Used for the 1-worker fast path and as the L2 fusion baseline in the
    perf pass (one HLO module: fwd + bwd + update, donated params).
    """
    out = grad_step(w1, b1, w2, b2, w3, b3, x, y)
    grads, loss = out[:-1], out[-1]
    params = (w1, b1, w2, b2, w3, b3)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new_params, loss)


def predict(w1, b1, w2, b2, w3, b3, x):
    """Inference: logits (the SS4.3 inference-service artifact)."""
    return apply(w1, b1, w2, b2, w3, b3, x)


def eval_step(w1, b1, w2, b2, w3, b3, x, y):
    """Held-out evaluation: (sum nll, correct count) for model selection."""
    logits = apply(w1, b1, w2, b2, w3, b3, x)
    logp = _log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == y.astype(jnp.int32)).astype(jnp.float32)
    )
    return jnp.sum(nll), correct
