"""AOT-lower the L2 graphs to HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids, so text round-trips cleanly. Lowered with
``return_tuple=True``; the Rust side unwraps with ``to_tuple()``.

Emits, per model variant (mlp-small / mlp-medium / mlp-large):

  artifacts/grad_step_<variant>.hlo.txt   fwd+bwd -> (grads..., loss)
  artifacts/train_step_<variant>.hlo.txt  fused fwd+bwd+SGD (1-worker path)
  artifacts/predict_<variant>.hlo.txt     logits (inference service)
  artifacts/eval_<variant>.hlo.txt        (sum nll, correct) for selection

plus the EP workflow kernel:

  artifacts/ep.hlo.txt                    (q[10], s[3]) per counter range

and artifacts/manifest.json describing every entry point's arguments so
the Rust runtime can validate shapes at load time.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ep

TRAIN_BATCH = 128
PREDICT_BATCH = 256
EVAL_BATCH = 256
EP_SAMPLES_PER_CALL = 1 << 16  # 65536 candidate pairs per PJRT execution


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="float32"):
    return jax.ShapeDtypeStruct(shape, getattr(jnp, dtype))


def _arg_entry(name, shape, dtype="float32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def lower_variant(variant, batch=TRAIN_BATCH):
    """Lower all four entry points of one classifier variant."""
    shapes = model.param_shapes(variant)
    params = [_spec(s) for _, s in shapes]
    x_train = _spec((batch, model.INPUT_DIM))
    y_train = _spec((batch,), "int32")
    x_pred = _spec((PREDICT_BATCH, model.INPUT_DIM))
    x_eval = _spec((EVAL_BATCH, model.INPUT_DIM))
    y_eval = _spec((EVAL_BATCH,), "int32")
    lr = _spec((), "float32")

    param_args = [_arg_entry(n, s) for n, s in shapes]
    entries = {}

    lowered = jax.jit(model.grad_step).lower(*params, x_train, y_train)
    entries[f"grad_step_{variant}"] = {
        "hlo": f"grad_step_{variant}.hlo.txt",
        "text": to_hlo_text(lowered),
        "args": param_args
        + [
            _arg_entry("x", (batch, model.INPUT_DIM)),
            _arg_entry("y", (batch,), "int32"),
        ],
        "outputs": [_arg_entry(f"g_{n}", s) for n, s in shapes]
        + [_arg_entry("loss", ())],
    }

    lowered = jax.jit(model.train_step).lower(*params, x_train, y_train, lr)
    entries[f"train_step_{variant}"] = {
        "hlo": f"train_step_{variant}.hlo.txt",
        "text": to_hlo_text(lowered),
        "args": param_args
        + [
            _arg_entry("x", (batch, model.INPUT_DIM)),
            _arg_entry("y", (batch,), "int32"),
            _arg_entry("lr", ()),
        ],
        "outputs": param_args + [_arg_entry("loss", ())],
    }

    lowered = jax.jit(model.predict).lower(*params, x_pred)
    entries[f"predict_{variant}"] = {
        "hlo": f"predict_{variant}.hlo.txt",
        "text": to_hlo_text(lowered),
        "args": param_args + [_arg_entry("x", (PREDICT_BATCH, model.INPUT_DIM))],
        "outputs": [_arg_entry("logits", (PREDICT_BATCH, model.NUM_CLASSES))],
    }

    lowered = jax.jit(model.eval_step).lower(*params, x_eval, y_eval)
    entries[f"eval_{variant}"] = {
        "hlo": f"eval_{variant}.hlo.txt",
        "text": to_hlo_text(lowered),
        "args": param_args
        + [
            _arg_entry("x", (EVAL_BATCH, model.INPUT_DIM)),
            _arg_entry("y", (EVAL_BATCH,), "int32"),
        ],
        "outputs": [_arg_entry("nll_sum", ()), _arg_entry("correct", ())],
    }
    return entries


def lower_ep():
    def ep_fn(seed, base):
        return ep.ep_gaussian_pairs(seed, base, EP_SAMPLES_PER_CALL)

    lowered = jax.jit(ep_fn).lower(
        _spec((), "uint32"), _spec((), "uint32")
    )
    return {
        "ep": {
            "hlo": "ep.hlo.txt",
            "text": to_hlo_text(lowered),
            "args": [
                _arg_entry("seed", (), "uint32"),
                _arg_entry("base", (), "uint32"),
            ],
            "outputs": [_arg_entry("q", (10,)), _arg_entry("s", (3,))],
            "samples_per_call": EP_SAMPLES_PER_CALL,
        }
    }


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--variants", default=",".join(model.VARIANTS), help="comma-separated"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = {}
    for variant in args.variants.split(","):
        entries.update(lower_variant(variant))
        print(f"lowered {variant}")
    entries.update(lower_ep())
    print("lowered ep")

    manifest = {"train_batch": TRAIN_BATCH, "predict_batch": PREDICT_BATCH,
                "eval_batch": EVAL_BATCH, "entries": {}}
    for name, entry in entries.items():
        path = os.path.join(args.out_dir, entry["hlo"])
        with open(path, "w") as f:
            f.write(entry["text"])
        manifest["entries"][name] = {
            k: v for k, v in entry.items() if k != "text"
        }
        print(f"wrote {path} ({len(entry['text'])} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
