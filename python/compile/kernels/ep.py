"""NAS EP (Embarrassingly Parallel) Gaussian-pair kernel in Pallas.

The SS4.2 Argo workflow (paper Listing 2) runs the NAS ``ep.A.x``
executable with varying ``--ntasks``. EP generates pseudo-random uniform
pairs, applies the Marsaglia polar method to obtain Gaussian deviates,
and tallies them into 10 annuli (deciles of ``max(|X|, |Y|)``) plus the
running sums ``sx``/``sy``. Work is split by giving each task a disjoint
range of counter values, which is exactly how the Slurm ``--ntasks``
annotation fans the kernel out in our reproduction.

Instead of NAS's 46-bit LCG (awkward in f32/u32 vector lanes) we use a
counter-based bijective integer hash (murmur3 finalizer) -- the standard
TPU-friendly choice (cf. threefry): stateless, order-independent, and
identical across JAX, the jnp oracle (ref.py) and the Rust baseline
(``workloads::ep``), so all three tallies can be cross-checked.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Samples processed per grid step: one VMEM-resident vector batch.
BLOCK = 4096


def _hash_u32(x):
    """Murmur3 finalizer: bijective u32 -> u32 mix, vectorizable on VPU."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def _uniform_pm1(bits):
    """u32 -> f32 uniform in (-1, 1), using the top 24 bits."""
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)
    return 2.0 * u - 1.0


def pairs_block(seed, base, n):
    """Generate ``n`` candidate pairs for counters ``base .. base+n-1``.

    Shared between the Pallas kernel and the jnp oracle so that both see
    bit-identical streams.
    """
    idx = base + jnp.arange(n, dtype=jnp.uint32)
    s = jnp.uint32(seed) * jnp.uint32(0x9E3779B9)
    x = _uniform_pm1(_hash_u32(idx * jnp.uint32(2) + s))
    y = _uniform_pm1(_hash_u32(idx * jnp.uint32(2) + jnp.uint32(1) + s))
    return x, y


def tally_block(x, y):
    """Marsaglia polar method + decile tally for one block of pairs.

    Returns ``(q, sx, sy, accepted)`` where ``q`` is the 10-bin histogram
    of ``floor(max(|X|, |Y|))`` over accepted pairs.
    """
    t = x * x + y * y
    accept = (t <= 1.0) & (t > 0.0)
    # Guard the log against t=0 / rejected lanes.
    t_safe = jnp.where(accept, t, 1.0)
    f = jnp.sqrt(-2.0 * jnp.log(t_safe) / t_safe)
    gx = jnp.where(accept, x * f, 0.0)
    gy = jnp.where(accept, y * f, 0.0)
    m = jnp.maximum(jnp.abs(gx), jnp.abs(gy))
    bins = jnp.clip(jnp.floor(m), 0.0, 9.0).astype(jnp.int32)
    # One-hot tally; rejected lanes contribute nothing.
    onehot = (bins[:, None] == jnp.arange(10, dtype=jnp.int32)[None, :]) & accept[:, None]
    q = jnp.sum(onehot.astype(jnp.float32), axis=0)
    return q, jnp.sum(gx), jnp.sum(gy), jnp.sum(accept.astype(jnp.float32))


def _ep_kernel(seed_ref, base_ref, q_ref, s_ref, *, block: int):
    """Grid step i tallies counters [base + i*block, base + (i+1)*block)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        q_ref[...] = jnp.zeros_like(q_ref)
        s_ref[...] = jnp.zeros_like(s_ref)

    base = base_ref[0] + jnp.uint32(i) * jnp.uint32(block)
    x, y = pairs_block(seed_ref[0], base, block)
    q, sx, sy, acc = tally_block(x, y)
    q_ref[...] += q
    s_ref[...] += jnp.stack([sx, sy, acc])


def ep_gaussian_pairs(seed, base, n, block=BLOCK):
    """Tally ``n`` candidate pairs starting at counter ``base``.

    Args:
      seed: u32 scalar array -- experiment seed (same for all tasks).
      base: u32 scalar array -- first counter of this task's range.
      n: static int -- number of candidate pairs (multiple of ``block``).

    Returns:
      ``(q, s)``: ``q`` f32[10] decile counts, ``s`` f32[3] = (sx, sy,
      accepted count).
    """
    assert n % block == 0, f"n={n} must be a multiple of block={block}"
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_ep_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=[
            pl.BlockSpec((10,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((10,), jnp.float32),
            jax.ShapeDtypeStruct((3,), jnp.float32),
        ],
        interpret=True,
    )(seed.reshape(1), base.reshape(1))
