"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness reference: pytest asserts the Pallas kernels
match these to float tolerance across shape/dtype sweeps (hypothesis).
"""

import jax.numpy as jnp

from . import ep as _ep


def matmul_bias_act_ref(x, w, b, activation="none"):
    """Reference for kernels.matmul.matmul_bias_act."""
    out = jnp.matmul(x, w, preferred_element_type=jnp.float32) + b
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def ep_gaussian_pairs_ref(seed, base, n):
    """Reference for kernels.ep.ep_gaussian_pairs (single un-tiled block)."""
    x, y = _ep.pairs_block(seed, base, n)
    q, sx, sy, acc = _ep.tally_block(x, y)
    return q, jnp.stack([sx, sy, acc])
