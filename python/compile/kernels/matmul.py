"""Tiled matmul + bias + activation Pallas kernel with a custom VJP.

This is the L1 hot spot of the SS4.3 training workload: every dense layer
of the classifier (fwd activations, and both backward GEMMs ``dx = g @ W^T``
and ``dW = x^T @ g``) runs through :func:`matmul_bias_act`.

TPU mapping (DESIGN.md SSHardware-Adaptation): the grid iterates
``(M/bm, N/bn, K/bk)`` with VMEM-resident ``(bm, bk) x (bk, bn)`` tiles
feeding the MXU; the K axis is the innermost (fastest-varying) grid
dimension so the f32 accumulator tile stays resident in VMEM across the
K loop (revolving output window). On this testbed kernels execute via
``interpret=True`` so tiling is validated structurally, not for wallclock.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: 128 matches the MXU systolic-array edge; a
# (128, 128) f32 tile is 64 KiB, so x/w/o tiles plus double-buffering fit
# comfortably in ~16 MiB VMEM (see EXPERIMENTS.md SSPerf-L1 for the model).
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str, k_steps: int):
    """Grid point (i, j, k): o[i, j] += x[i, k] @ w[k, j]; epilogue on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...]
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


def _matmul_bias_act_fwd_impl(x, w, b, activation, bm, bn, bk):
    """Raw pallas call; pads inputs to tile multiples and slices back."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    bk = min(bk, max(8, k))

    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    bp = _pad_to(b.reshape(1, n), bn, 1)

    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(
            _matmul_kernel, activation=activation, k_steps=grid[2]
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def matmul_bias_act(x, w, b, activation="none", bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """``act(x @ w + b)`` as a tiled Pallas kernel.

    Args:
      x: ``(M, K)`` f32 activations.
      w: ``(K, N)`` f32 weights.
      b: ``(N,)`` f32 bias.
      activation: ``"none"`` or ``"relu"``.
      bm/bn/bk: tile sizes (static).

    Returns:
      ``(M, N)`` f32.

    Differentiable via a custom VJP whose backward GEMMs also run through
    the Pallas kernel (so the AOT-lowered train step is Pallas end-to-end).
    """
    return _matmul_bias_act_fwd_impl(x, w, b, activation, bm, bn, bk)


def _fwd(x, w, b, activation, bm, bn, bk):
    out = _matmul_bias_act_fwd_impl(x, w, b, activation, bm, bn, bk)
    return out, (x, w, out)


def _bwd(activation, bm, bn, bk, res, g):
    x, w, out = res
    if activation == "relu":
        g = jnp.where(out > 0.0, g, 0.0)
    n = w.shape[1]
    k = w.shape[0]
    zero_n = jnp.zeros((n,), jnp.float32)
    zero_k = jnp.zeros((k,), jnp.float32)
    # dx = g @ w^T, dw = x^T @ g -- both through the Pallas kernel.
    dx = _matmul_bias_act_fwd_impl(g, w.T, zero_k, "none", bm, bk, bn)
    dw = _matmul_bias_act_fwd_impl(x.T, g, zero_n, "none", bk, bn, bm)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


matmul_bias_act.defvjp(_fwd, _bwd)
