"""L1 Pallas kernels for HPK's compute workloads.

Two kernels back the paper's evaluation workloads:

- ``matmul``: tiled matmul + bias + optional ReLU, the hot spot of the
  SS4.3 distributed-training classifier (every layer's fwd and bwd GEMMs
  route through it).
- ``ep``: the NAS EP (Embarrassingly Parallel) Gaussian-pair kernel used
  by the SS4.2 Argo/MPI workflow step.

All kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); see DESIGN.md SSHardware-Adaptation.
"""

from .matmul import matmul_bias_act  # noqa: F401
from .ep import ep_gaussian_pairs  # noqa: F401
