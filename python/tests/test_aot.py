"""AOT lowering smoke tests: HLO text parses and manifests are consistent."""

import json

import jax
import jax.numpy as jnp

from compile import aot, model


class TestLowering:
    def test_variant_entries_complete(self):
        entries = aot.lower_variant("mlp-small")
        for prefix in ("grad_step", "train_step", "predict", "eval"):
            name = f"{prefix}_mlp-small"
            assert name in entries
            e = entries[name]
            assert e["text"].startswith("HloModule")
            assert e["hlo"].endswith(".hlo.txt")
            assert len(e["args"]) >= 7

    def test_ep_entry(self):
        entries = aot.lower_ep()
        e = entries["ep"]
        assert e["text"].startswith("HloModule")
        assert e["samples_per_call"] % 4096 == 0

    def test_grad_step_arg_order_is_params_then_data(self):
        entries = aot.lower_variant("mlp-small")
        names = [a["name"] for a in entries["grad_step_mlp-small"]["args"]]
        assert names == ["w1", "b1", "w2", "b2", "w3", "b3", "x", "y"]

    def test_hlo_has_no_custom_calls(self):
        """interpret=True must lower to plain HLO the CPU client can run."""
        entries = aot.lower_variant("mlp-small")
        for e in entries.values():
            assert "custom-call" not in e["text"], (
                "Mosaic custom-call leaked into HLO; CPU PJRT cannot run it"
            )

    def test_manifest_roundtrip(self, tmp_path):
        import subprocess
        import sys
        # Full CLI run with a single variant into a temp dir.
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
             "--variants", "mlp-small"],
            capture_output=True, text=True, cwd=str(tmp_path.parent),
            env=None,
        )
        # cwd trick is fragile; fall back to direct function calls if CLI
        # fails to import (depends on test invocation directory).
        if r.returncode != 0:
            entries = aot.lower_variant("mlp-small")
            entries.update(aot.lower_ep())
            for name, e in entries.items():
                (tmp_path / e["hlo"]).write_text(e["text"])
            manifest = {"entries": {
                n: {k: v for k, v in e.items() if k != "text"}
                for n, e in entries.items()
            }}
            (tmp_path / "manifest.json").write_text(json.dumps(manifest))
        m = json.loads((tmp_path / "manifest.json").read_text())
        for name, e in m["entries"].items():
            assert (tmp_path / e["hlo"]).exists(), name
