"""L2 model checks: shapes, gradient agreement, and learning signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _toy_batch(batch=32, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.uniform(kx, (batch, model.INPUT_DIM), jnp.float32)
    y = jax.random.randint(ky, (batch,), 0, model.NUM_CLASSES, jnp.int32)
    return x, y


@pytest.fixture(scope="module")
def params():
    return model.init_params("mlp-small", jax.random.PRNGKey(0))


class TestModelShapes:
    @pytest.mark.parametrize("variant", list(model.VARIANTS))
    def test_param_shapes(self, variant):
        shapes = model.param_shapes(variant)
        h1, h2 = model.VARIANTS[variant]
        assert shapes[0][1] == (model.INPUT_DIM, h1)
        assert shapes[2][1] == (h1, h2)
        assert shapes[4][1] == (h2, model.NUM_CLASSES)

    def test_apply_logits_shape(self, params):
        x, _ = _toy_batch()
        logits = model.apply(*params, x)
        assert logits.shape == (32, model.NUM_CLASSES)

    def test_grad_step_output_arity(self, params):
        x, y = _toy_batch()
        out = model.grad_step(*params, x, y)
        assert len(out) == 7  # 6 grads + loss
        for g, p in zip(out[:-1], params):
            assert g.shape == p.shape

    def test_eval_step_counts(self, params):
        x, y = _toy_batch(64)
        nll_sum, correct = model.eval_step(*params, x, y)
        assert nll_sum.shape == ()
        assert 0 <= float(correct) <= 64


class TestTraining:
    def test_loss_is_near_chance_at_init(self, params):
        # He-init logits on random uniform inputs: loss should be in the
        # vicinity of log(C)=2.3, not collapsed (0) nor exploded.
        x, y = _toy_batch(64)
        loss = float(model.loss_fn(*params, x, y))
        assert 1.0 < loss < 8.0, loss

    def test_train_step_reduces_loss(self, params):
        x, y = _toy_batch(64)
        p = params
        first = None
        for _ in range(20):
            out = model.train_step(*p, x, y, jnp.float32(0.1))
            p, loss = out[:-1], out[-1]
            if first is None:
                first = float(loss)
        assert float(loss) < first - 0.1

    def test_grad_step_equals_train_step_update(self, params):
        """train_step must be exactly grad_step + SGD (the 1-worker fusion)."""
        x, y = _toy_batch(16, seed=3)
        lr = jnp.float32(0.05)
        gout = model.grad_step(*params, x, y)
        tout = model.train_step(*params, x, y, lr)
        for p, g, t in zip(params, gout[:-1], tout[:-1]):
            np.testing.assert_allclose(p - lr * g, t, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(gout[-1], tout[-1], rtol=1e-6)

    def test_gradients_match_pure_jnp_model(self, params):
        """End-to-end: Pallas-backed grads == pure-jnp model grads."""
        from compile.kernels import ref

        def jnp_loss(w1, b1, w2, b2, w3, b3, x, y):
            h = ref.matmul_bias_act_ref(x, w1, b1, "relu")
            h = ref.matmul_bias_act_ref(h, w2, b2, "relu")
            logits = ref.matmul_bias_act_ref(h, w3, b3, "none")
            logp = logits - jax.scipy.special.logsumexp(
                logits, axis=-1, keepdims=True
            )
            return -jnp.mean(
                jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), -1)
            )

        x, y = _toy_batch(16, seed=5)
        g_pallas = jax.grad(model.loss_fn, argnums=(0, 2, 4))(*params, x, y)
        g_jnp = jax.grad(jnp_loss, argnums=(0, 2, 4))(*params, x, y)
        for a, e in zip(g_pallas, g_jnp):
            np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5)
