"""Kernel-vs-oracle correctness: the CORE L1 signal.

hypothesis sweeps shapes (and tile sizes) of the Pallas matmul kernel and
asserts allclose against the pure-jnp reference; the EP kernel's tiled
tally is checked against the un-tiled oracle exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_bias_act, ep_gaussian_pairs
from compile.kernels import ref
from compile.kernels import ep as ep_mod


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestMatmulKernel:
    @pytest.mark.parametrize("activation", ["none", "relu"])
    def test_square_matches_ref(self, activation):
        x, w = _rand(0, (64, 64)), _rand(1, (64, 64))
        b = _rand(2, (64,))
        out = matmul_bias_act(x, w, b, activation)
        expect = ref.matmul_bias_act_ref(x, w, b, activation)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)

    def test_non_divisible_shapes_padded(self):
        # 28*28=784 inputs and 10 classes are not tile multiples.
        x, w, b = _rand(0, (37, 784)), _rand(1, (784, 10)), _rand(2, (10,))
        out = matmul_bias_act(x, w, b, "none")
        expect = ref.matmul_bias_act_ref(x, w, b, "none")
        assert out.shape == (37, 10)
        # K=784 accumulates in tile order; allow reassociation slack.
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 96),
        k=st.integers(1, 96),
        n=st.integers(1, 96),
        bm=st.sampled_from([8, 16, 32, 128]),
        bn=st.sampled_from([8, 16, 32, 128]),
        bk=st.sampled_from([8, 16, 32, 128]),
        activation=st.sampled_from(["none", "relu"]),
        seed=st.integers(0, 2**16),
    )
    def test_shape_tile_sweep(self, m, k, n, bm, bn, bk, activation, seed):
        x, w = _rand(seed, (m, k)), _rand(seed + 1, (k, n))
        b = _rand(seed + 2, (n,))
        out = matmul_bias_act(x, w, b, activation, bm, bn, bk)
        expect = ref.matmul_bias_act_ref(x, w, b, activation)
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)

    def test_relu_clamps_negatives(self):
        x = jnp.ones((4, 4), jnp.float32)
        w = -jnp.eye(4, dtype=jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        out = matmul_bias_act(x, w, b, "relu")
        assert float(jnp.max(out)) == 0.0

    def test_custom_vjp_matches_jnp_grad(self):
        x, w, b = _rand(0, (16, 24)), _rand(1, (24, 12)), _rand(2, (12,))

        def f_kernel(x, w, b):
            return jnp.sum(matmul_bias_act(x, w, b, "relu") ** 2)

        def f_ref(x, w, b):
            return jnp.sum(ref.matmul_bias_act_ref(x, w, b, "relu") ** 2)

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
        for a, e in zip(gk, gr):
            np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)

    def test_dtype_is_f32(self):
        x, w, b = _rand(0, (8, 8)), _rand(1, (8, 8)), _rand(2, (8,))
        assert matmul_bias_act(x, w, b).dtype == jnp.float32


class TestEpKernel:
    def test_matches_ref_exactly(self):
        seed = jnp.uint32(42)
        base = jnp.uint32(0)
        n = 4 * ep_mod.BLOCK
        q, s = ep_gaussian_pairs(seed, base, n)
        qr, sr = ref.ep_gaussian_pairs_ref(seed, base, n)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31), blocks=st.integers(1, 4))
    def test_seed_sweep_matches_ref(self, seed, blocks):
        s32 = jnp.uint32(seed)
        base = jnp.uint32(0)
        n = blocks * ep_mod.BLOCK
        q, s = ep_gaussian_pairs(s32, base, n)
        qr, sr = ref.ep_gaussian_pairs_ref(s32, base, n)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-4)

    def test_disjoint_ranges_compose(self):
        """Task-parallel decomposition: two half-ranges sum to the full."""
        seed = jnp.uint32(7)
        n = 2 * ep_mod.BLOCK
        q_full, s_full = ep_gaussian_pairs(seed, jnp.uint32(0), 2 * n)
        q_a, s_a = ep_gaussian_pairs(seed, jnp.uint32(0), n)
        q_b, s_b = ep_gaussian_pairs(seed, jnp.uint32(n), n)
        np.testing.assert_array_equal(
            np.asarray(q_full), np.asarray(q_a) + np.asarray(q_b)
        )
        np.testing.assert_allclose(
            np.asarray(s_full), np.asarray(s_a) + np.asarray(s_b), rtol=1e-4
        )

    def test_acceptance_rate_near_pi_over_4(self):
        q, s = ep_gaussian_pairs(jnp.uint32(3), jnp.uint32(0), 8 * ep_mod.BLOCK)
        rate = float(s[2]) / (8 * ep_mod.BLOCK)
        assert abs(rate - np.pi / 4) < 0.01

    def test_gaussian_moments(self):
        """Accepted deviates should have ~zero mean (sx, sy ~ 0)."""
        q, s = ep_gaussian_pairs(jnp.uint32(9), jnp.uint32(0), 16 * ep_mod.BLOCK)
        n_acc = float(s[2])
        assert abs(float(s[0]) / n_acc) < 0.02
        assert abs(float(s[1]) / n_acc) < 0.02

    def test_decile_counts_decrease(self):
        """|N(0,1)| mass falls off with the annulus index."""
        q, _ = ep_gaussian_pairs(jnp.uint32(1), jnp.uint32(0), 16 * ep_mod.BLOCK)
        qn = np.asarray(q)
        assert qn[0] > qn[1] > qn[2]
        assert qn[0] + qn[1] + qn[2] > 0.99 * qn.sum()
